//! Block Krylov–Schur (thick-restarted block Lanczos) eigensolver over
//! SEM-SpMM (§4.2, Fig 15).
//!
//! For a symmetric adjacency matrix the Krylov–Schur method reduces to
//! thick-restarted Lanczos. Each restart cycle:
//!
//! 1. **Expand** the subspace V (n×m, stored as b-column panels either in
//!    memory — SEM-max — or on the store — SEM-min) by repeatedly
//!    multiplying the last block with A (SEM-SpMM with p = b) and fully
//!    reorthogonalizing against all panels (power-law spectra make
//!    selective reorthogonalization unreliable).
//! 2. **Rayleigh–Ritz**: T = Vᵀ A V (m×m) is diagonalized with the dense
//!    Jacobi solver; Ritz vectors U = V·Y. With the subspace in memory
//!    the projection dot-products Vᵢᵀ·(A pⱼ) are fused into the SpMM
//!    streaming pass itself (a [`crate::spmm::StreamPass`] hook runs on
//!    every finished output interval while the rows are hot), replacing
//!    the old np² post-SpMM sweeps over the tall panels.
//! 3. **Thick restart**: keep the best `nev + pad` Ritz vectors as the new
//!    basis and iterate until the wanted residuals ‖A u − θ u‖ converge.
//!
//! All tall algebra streams panel-by-panel through [`super::TallPanels`],
//! so SEM-min holds only O(n·b) floats in memory while the subspace and
//! its image under A live on the store — the paper's "both the sparse
//! matrix and the vector subspace on SSDs".

use super::TallPanels;
use crate::io::{CacheUsage, ShardedStore};
use crate::matrix::{ops, DenseMatrix, NumaDense};
use crate::metrics::Stopwatch;
use crate::spmm::{engine, exec, OutputSink, RowHook, Source, SpmmOpts, StreamPass};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Subspace placement (Fig 15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubspaceMem {
    /// Entire subspace in memory (SEM-max / IM).
    Mem,
    /// Subspace panels on the store (SEM-min).
    Sem,
}

/// Eigensolver configuration.
#[derive(Debug, Clone)]
pub struct EigenConfig {
    /// Wanted eigenpairs (largest algebraic).
    pub nev: usize,
    /// Block size (the paper's KrylovSchur updates 1–4 vectors at a time).
    pub block: usize,
    /// Max subspace dimension (multiple of `block`; default 4·nev).
    pub subspace: usize,
    pub tol: f64,
    pub max_restarts: usize,
    pub placement: SubspaceMem,
    pub spmm: SpmmOpts,
    pub seed: u64,
}

impl Default for EigenConfig {
    fn default() -> Self {
        EigenConfig {
            nev: 8,
            block: 4,
            subspace: 32,
            tol: 1e-6,
            max_restarts: 60,
            placement: SubspaceMem::Mem,
            spmm: SpmmOpts::default(),
            seed: 0xE16E,
        }
    }
}

/// Result: eigenvalues (descending), residuals, and run stats.
#[derive(Debug, Clone)]
pub struct EigenResult {
    /// Converged eigenvalues, largest first.
    pub eigenvalues: Vec<f64>,
    /// Residual norms `‖A u − θ u‖` of the wanted pairs.
    pub residuals: Vec<f64>,
    /// Restart cycles executed.
    pub restarts: usize,
    /// Wall-clock seconds.
    pub secs: f64,
    /// SEM-SpMM invocations (each a full pass over the matrix).
    pub spmm_calls: usize,
    /// Logical bytes read at the array interface.
    pub bytes_read: u64,
    /// Logical bytes written at the array interface.
    pub bytes_written: u64,
    /// Tile-row cache activity (when the SpMM options carried a cache
    /// budget and the matrix is SEM) — the repeated expansion/Rayleigh-
    /// Ritz passes are exactly the traffic the cache absorbs.
    pub cache: Option<CacheUsage>,
}

/// Compute the `nev` largest-algebraic eigenpairs of a symmetric sparse
/// matrix. Returns eigenvalues; eigenvectors stay in `v_out` panels when
/// provided.
pub fn eigensolve(
    src: &Source,
    store: &Arc<ShardedStore>,
    cfg: &EigenConfig,
) -> Result<EigenResult> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n {
        bail!("eigensolver needs a square (symmetric) matrix");
    }
    let b = cfg.block.max(1);
    let m = cfg.subspace.max(2 * b);
    if m % b != 0 {
        bail!("subspace ({m}) must be a multiple of block ({b})");
    }
    let np = m / b;
    let keep_panels = (cfg.nev.div_ceil(b) + 1).min(np - 1);
    let in_mem = cfg.placement == SubspaceMem::Mem;

    let read0 = store.stats.bytes_read.get();
    let written0 = store.stats.bytes_written.get();
    // Resolve the cache this run will use up front, so the baseline and
    // the final reading come from the same cache across budget changes.
    let cache = src.resolve_tile_cache(&cfg.spmm);
    let cache0 = cache.as_ref().map(|c| c.usage()).unwrap_or_default();
    let sw = Stopwatch::start();
    let mut spmm_calls = 0usize;

    let mut v = TallPanels::create(store, "eigen.V", n, b, np, in_mem)?;
    let mut av = TallPanels::create(store, "eigen.AV", n, b, np, in_mem)?;

    // Initial block: random, orthonormalized.
    {
        let mut p0 = DenseMatrix::random(n, b, cfg.seed);
        for val in &mut p0.data {
            *val -= 0.5;
        }
        ops::orthonormalize(&mut p0, None);
        v.store(0, &p0)?;
    }
    let mut active = 1usize; // panels currently valid

    let mut eigenvalues = Vec::new();
    let mut residuals = Vec::new();
    let mut restarts = 0usize;
    let mut converged = false;

    while restarts < cfg.max_restarts && !converged {
        restarts += 1;
        // --- 1. Expansion: grow to the full subspace.
        while active < np {
            let last = v.load(active - 1)?;
            let (mut w, _) = engine::spmm_out(src, &last, &cfg.spmm)?;
            spmm_calls += 1;
            // Full reorthogonalization against all existing panels, twice.
            for _pass in 0..2 {
                for i in 0..active {
                    let pi = v.load(i)?;
                    let c = ops::xty(&pi, &w);
                    let corr = ops::mul_small(&pi, &c);
                    ops::axpy(&mut w, -1.0, &corr);
                }
            }
            let norms = ops::orthonormalize(&mut w, None);
            // Rank collapse → reseed the dead directions randomly.
            if norms.iter().any(|&x| x < 1e-10) {
                let mut r = DenseMatrix::random(n, b, cfg.seed ^ ((active as u64) << 8));
                for val in &mut r.data {
                    *val -= 0.5;
                }
                for (j, &x) in norms.iter().enumerate() {
                    if x < 1e-10 {
                        for row in 0..n {
                            w.set(row, j, r.get(row, j));
                        }
                    }
                }
                for _pass in 0..2 {
                    for i in 0..active {
                        let pi = v.load(i)?;
                        let c = ops::xty(&pi, &w);
                        let corr = ops::mul_small(&pi, &c);
                        ops::axpy(&mut w, -1.0, &corr);
                    }
                }
                ops::orthonormalize(&mut w, None);
            }
            v.store(active, &w)?;
            active += 1;
        }

        // --- 2. Rayleigh–Ritz: T = Vᵀ (A V).
        //
        // With the subspace in memory (SEM-max / IM) every projection
        // block Vᵢᵀ·(A pⱼ) is **fused into the SpMM pass**: a hook
        // accumulates all np b×b blocks while each output row interval
        // of A·pⱼ is still hot, so the old np² post-SpMM sweeps over the
        // tall panels disappear. SEM-min keeps the explicit sweeps — its
        // panels live on the store and cannot be read from a hook.
        let mut t = DenseMatrix::zeros(m, m);
        for j in 0..np {
            let pj = v.load(j)?;
            if in_mem {
                let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
                let xj = NumaDense::from_dense(&pj, ncfg);
                let apj_nd = NumaDense::zeros(n, b, ncfg);
                let v_ref = &v;
                let hook: RowHook =
                    Box::new(move |rows_lo: usize, rows: &mut [f32], acc: &mut [f64]| {
                    let nloc = rows.len() / b;
                    for i in 0..np {
                        let pi = v_ref.panel_ref(i).expect("in-memory panel");
                        let ablk = &mut acc[i * b * b..(i + 1) * b * b];
                        for r in 0..nloc {
                            let prow = pi.row(rows_lo + r);
                            let orow = &rows[r * b..(r + 1) * b];
                            for (bi, &x) in prow.iter().enumerate() {
                                if x != 0.0 {
                                    let arow = &mut ablk[bi * b..(bi + 1) * b];
                                    for (a, &o) in arow.iter_mut().zip(orow) {
                                        *a += x as f64 * o as f64;
                                    }
                                }
                            }
                        }
                    }
                });
                let pass = StreamPass::new().forward_with(
                    &xj,
                    OutputSink::Mem(&apj_nd),
                    np * b * b,
                    hook,
                );
                let r = exec::run_pass(src, &pass, &cfg.spmm)?;
                spmm_calls += 1;
                av.store(j, &apj_nd.to_dense())?;
                for i in 0..np {
                    for bi in 0..b {
                        for bj in 0..b {
                            t.set(
                                i * b + bi,
                                j * b + bj,
                                r.accs[0][(i * b + bi) * b + bj] as f32,
                            );
                        }
                    }
                }
            } else {
                let (apj, _) = engine::spmm_out(src, &pj, &cfg.spmm)?;
                spmm_calls += 1;
                av.store(j, &apj)?;
                for i in 0..np {
                    let pi = v.load(i)?;
                    let blk = ops::xty(&pi, &apj); // b×b
                    for bi in 0..b {
                        for bj in 0..b {
                            t.set(i * b + bi, j * b + bj, blk.get(bi, bj));
                        }
                    }
                }
            }
        }
        // Symmetrize (A is symmetric; numerical noise breaks it slightly).
        for i in 0..m {
            for j in (i + 1)..m {
                let s = 0.5 * (t.get(i, j) + t.get(j, i));
                t.set(i, j, s);
                t.set(j, i, s);
            }
        }
        let (theta, y) = ops::jacobi_eig(&t); // ascending
        // Order of interest: largest algebraic first.
        let order: Vec<usize> = (0..m).rev().collect();

        // --- 3. Ritz vectors for the kept window + residuals.
        let keep = keep_panels * b;
        let mut y_keep = DenseMatrix::zeros(m, keep);
        for (col, &src_col) in order.iter().take(keep).enumerate() {
            for row in 0..m {
                y_keep.set(row, col, y.get(row, src_col));
            }
        }
        // U = V · Y_keep, AU = AV · Y_keep, streamed panel-by-panel.
        let mut u = TallPanels::create(store, "eigen.U", n, b, keep_panels, in_mem)?;
        let mut au_res: Vec<f64> = vec![0.0; keep];
        for q in 0..keep_panels {
            let yq = y_keep.col_slice(q * b, (q + 1) * b);
            let mut acc_u = DenseMatrix::zeros(n, b);
            let mut acc_au = DenseMatrix::zeros(n, b);
            for j in 0..np {
                let yblk = {
                    // rows j*b..(j+1)*b of yq
                    let mut blk = DenseMatrix::zeros(b, b);
                    for bi in 0..b {
                        for bj in 0..b {
                            blk.set(bi, bj, yq.get(j * b + bi, bj));
                        }
                    }
                    blk
                };
                let pj = v.load(j)?;
                ops::axpy(&mut acc_u, 1.0, &ops::mul_small(&pj, &yblk));
                let apj = av.load(j)?;
                ops::axpy(&mut acc_au, 1.0, &ops::mul_small(&apj, &yblk));
            }
            // Residual per kept column: ‖AU_i − θ_i U_i‖.
            for bj in 0..b {
                let col = q * b + bj;
                let th = theta[order[col]];
                let mut s = 0f64;
                for row in 0..n {
                    let d = acc_au.get(row, bj) as f64 - th * acc_u.get(row, bj) as f64;
                    s += d * d;
                }
                au_res[col] = s.sqrt();
            }
            u.store(q, &acc_u)?;
        }

        eigenvalues = order
            .iter()
            .take(cfg.nev)
            .map(|&i| theta[i])
            .collect();
        residuals = au_res[..cfg.nev.min(keep)].to_vec();
        let scale = eigenvalues
            .iter()
            .fold(1f64, |a, &x| a.max(x.abs()));
        converged = residuals.iter().all(|&r| r < cfg.tol * scale);

        // --- Thick restart: new basis = kept Ritz vectors.
        for q in 0..keep_panels {
            let mut pq = u.load(q)?;
            // Re-orthonormalize defensively.
            if q > 0 {
                for i in 0..q {
                    let pi = v.load(i)?;
                    let c = ops::xty(&pi, &pq);
                    let corr = ops::mul_small(&pi, &c);
                    ops::axpy(&mut pq, -1.0, &corr);
                }
            }
            ops::orthonormalize(&mut pq, None);
            v.store(q, &pq)?;
        }
        active = keep_panels;
    }

    Ok(EigenResult {
        eigenvalues,
        residuals,
        restarts,
        secs: sw.secs(),
        spmm_calls,
        bytes_read: store.stats.bytes_read.get() - read0,
        bytes_written: store.stats.bytes_written.get() - written0,
        cache: cache.map(|c| c.usage().since(&cache0)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::rmat;
    use crate::io::StoreSpec;

    /// Dense oracle: eigenvalues via Jacobi on the dense adjacency.
    fn dense_eigs(m: &Csr) -> Vec<f64> {
        let n = m.nrows;
        let mut a = DenseMatrix::zeros(n, n);
        for r in 0..n {
            for &c in m.row(r) {
                a.set(r, c as usize, 1.0);
            }
        }
        let (mut ev, _) = ops::jacobi_eig(&a);
        ev.reverse(); // descending
        ev
    }

    fn sym_graph(scale: u32, edges: usize, seed: u64) -> Csr {
        let mut el = rmat::generate(scale, edges, rmat::RmatParams::default(), seed);
        el.symmetrize();
        Csr::from_edgelist(&el)
    }

    #[test]
    fn matches_dense_oracle_both_placements() {
        let m = sym_graph(8, 1500, 3); // 256 vertices
        let want = dense_eigs(&m);
        let img = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        for placement in [SubspaceMem::Mem, SubspaceMem::Sem] {
            let cfg = EigenConfig {
                nev: 4,
                block: 2,
                subspace: 16,
                tol: 1e-7,
                placement,
                spmm: SpmmOpts {
                    threads: 2,
                    ..Default::default()
                },
                ..Default::default()
            };
            let res = eigensolve(&Source::Mem(img.clone()), &store, &cfg).unwrap();
            for (i, ev) in res.eigenvalues.iter().enumerate() {
                assert!(
                    (ev - want[i]).abs() < 1e-3 * want[0].abs(),
                    "{placement:?} λ{i}: {ev} vs {}",
                    want[i]
                );
            }
            if placement == SubspaceMem::Sem {
                assert!(res.bytes_written > 0, "SEM-min must write the subspace");
            }
        }
    }

    #[test]
    fn residuals_converge() {
        let m = sym_graph(9, 3000, 7);
        let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = EigenConfig {
            nev: 3,
            block: 1,
            subspace: 12,
            tol: 1e-6,
            ..Default::default()
        };
        let res = eigensolve(&Source::Mem(img), &store, &cfg).unwrap();
        let scale = res.eigenvalues[0].abs();
        for r in &res.residuals {
            assert!(r / scale < 1e-5, "residual {r}");
        }
        // Eigenvalues descending.
        for w in res.eigenvalues.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
    }

    #[test]
    fn cached_sem_solve_matches_uncached_with_one_physical_pass() {
        // The eigensolver calls SEM-SpMM dozens of times per run; with a
        // full-size cache the store is only read on the very first pass.
        let m = sym_graph(8, 1500, 11);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let run = |budget: u64| {
            let dir = crate::util::tempdir();
            let store =
                ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
            store.put("eig.semm", &buf).unwrap();
            let sem = crate::spmm::SemSource::open(&store, "eig.semm").unwrap();
            let data_bytes = sem.data_bytes();
            let cfg = EigenConfig {
                nev: 3,
                block: 2,
                subspace: 12,
                tol: 1e-6,
                spmm: SpmmOpts {
                    threads: 2,
                    cache_budget_bytes: budget,
                    ..Default::default()
                },
                ..Default::default()
            };
            let res = eigensolve(&Source::Sem(sem), &store, &cfg).unwrap();
            (res, store.physical_bytes_read(), data_bytes)
        };
        let (cold, cold_phys, data_bytes) = run(0);
        let (warm, warm_phys, _) = run(u64::MAX);
        // The fused Rayleigh–Ritz reduction sums per-worker f64 partials
        // whose grouping follows the dynamic schedule, so two runs agree
        // to rounding (not bitwise) — the cache itself changes nothing.
        let scale = cold.eigenvalues[0].abs().max(1.0);
        for (i, (a, b)) in cold
            .eigenvalues
            .iter()
            .zip(&warm.eigenvalues)
            .enumerate()
        {
            assert!(
                (a - b).abs() < 1e-7 * scale,
                "λ{i}: cached {b} vs uncached {a}"
            );
        }
        assert!(cold.spmm_calls > 1, "solver must multiply repeatedly");
        // Uncached: every spmm pass re-reads the matrix. Cached: only the
        // first pass touches the device (plus the header/index open).
        assert!(cold_phys > 2 * data_bytes, "uncached run re-reads");
        assert!(
            warm_phys < data_bytes + 4096,
            "cached run read {warm_phys} bytes for a {data_bytes}-byte matrix"
        );
        let usage = warm.cache.expect("cache attached");
        assert!(usage.hits > usage.misses, "later passes must hit");
    }

    #[test]
    fn rejects_rectangular() {
        let mut pairs = vec![(0u32, 1u32), (1, 2)];
        pairs.sort_unstable();
        let m = Csr::from_sorted_pairs(3, 5, &pairs);
        let img = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        assert!(eigensolve(&Source::Mem(img), &store, &EigenConfig::default()).is_err());
    }
}
