//! Min-label propagation (connected components) as min-select sweeps.
//!
//! Under [`MinSelect`] (GraphBLAS `MIN_SECOND`), one streaming pass
//! `y = A ⊗ x` computes `y[v] = min { x[u] : u an in-neighbor of v }`,
//! ignoring edge values. Starting from `label[v] = v` and folding
//! `label' = min(y, label)` in a fused [`RowHook`], labels flood across
//! edges until a fixpoint: on a **symmetric** adjacency image every
//! vertex ends up labeled with the smallest vertex id of its connected
//! component — the classic min-label / hash-min connected-components
//! algorithm, running entirely on the SEM sweep (the matrix never
//! leaves the store; convergence takes at most diameter-many sweeps).
//!
//! On a directed (non-symmetric) image the fixpoint is still well
//! defined — each vertex gets the smallest label that can reach it —
//! but it is not "connected components"; symmetrize first (as the SBM
//! generator and [`crate::graph::EdgeList::symmetrize`] do).
//!
//! Labels ride the engine's `f32` elements, which represent integers
//! exactly only up to 2²⁴ — [`connected_components`] rejects larger
//! vertex counts instead of corrupting ids silently.

use crate::metrics::Stopwatch;
use crate::matrix::NumaDense;
use crate::spmm::{engine, exec, MinSelect, OutputSink, RowHook, Source, SpmmOpts, StreamPass};
use anyhow::{bail, Result};

/// Label-propagation configuration.
#[derive(Debug, Clone)]
pub struct LabelPropConfig {
    /// Sweep cap; the default runs to the fixpoint (at most
    /// diameter-many sweeps on a symmetric image).
    pub max_iters: usize,
    /// Engine options for each sweep.
    pub spmm: SpmmOpts,
}

impl Default for LabelPropConfig {
    fn default() -> Self {
        LabelPropConfig {
            max_iters: usize::MAX,
            spmm: SpmmOpts::default(),
        }
    }
}

/// Run statistics.
#[derive(Debug, Clone, Default)]
pub struct LabelPropStats {
    /// Wall-clock seconds of the whole run.
    pub secs: f64,
    /// Sweeps executed (including the final no-change sweep).
    pub iters: usize,
    /// Whether a sweep with zero label changes was reached.
    pub converged: bool,
    /// Number of distinct final labels (= connected components on a
    /// symmetric image after convergence).
    pub components: usize,
    /// Labels changed per sweep.
    pub changed: Vec<u64>,
    /// Logical sparse-matrix bytes read across all sweeps (SEM mode).
    pub bytes_read: u64,
}

/// Min-label propagation over an adjacency image; on a **symmetric**
/// image this computes connected components (`labels[v]` = smallest
/// vertex id in `v`'s component). Rejects `n > 2²⁴` (f32 exact-integer
/// ceiling for labels).
pub fn connected_components(
    src: &Source,
    cfg: &LabelPropConfig,
) -> Result<(Vec<u32>, LabelPropStats)> {
    connected_components_warm(src, None, cfg)
}

/// [`connected_components`] seeded from a previous labeling — the
/// incremental-refresh hook after delta-layer edge updates. Sound for
/// **edge insertions**: min-labels only ever decrease, so flooding from
/// the old fixpoint reaches the new one (usually in a couple of sweeps,
/// since only merged components move). After **deletions** a component
/// may split, which can only *raise* labels — warm-starting cannot do
/// that, so refresh from scratch (`warm = None`) when edges were
/// removed. `warm[v]` must be a vertex id `< n`.
pub fn connected_components_warm(
    src: &Source,
    warm: Option<&[u32]>,
    cfg: &LabelPropConfig,
) -> Result<(Vec<u32>, LabelPropStats)> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n {
        bail!("label propagation needs a square adjacency image");
    }
    if n > (1 << 24) {
        bail!("label propagation labels exceed the f32 exact-integer range (n = {n} > 2^24)");
    }
    if let Some(w) = warm {
        if w.len() != n {
            bail!("warm labeling has {} entries for {n} vertices", w.len());
        }
        if let Some(&l) = w.iter().find(|&&l| l as usize >= n) {
            bail!("warm label {l} is not a vertex id below {n}");
        }
    }
    let sw = Stopwatch::start();
    let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
    let mut x = NumaDense::zeros(n, 1, ncfg);
    let mut x_next = NumaDense::zeros(n, 1, ncfg);
    let mut label = NumaDense::zeros(n, 1, ncfg);
    for v in 0..n {
        let l = warm.map_or(v as f32, |w| w[v] as f32);
        x.row_mut(v)[0] = l;
        label.row_mut(v)[0] = l;
    }

    let mut iters = 0usize;
    let mut converged = false;
    let mut changed = Vec::new();
    let mut bytes_read = 0u64;
    while iters < cfg.max_iters {
        let lref = &label;
        // label' = min(neighborhood minimum, own label), folded while the
        // rows are hot; changed count drives convergence.
        let hook: RowHook = Box::new(move |lo: usize, rows: &mut [f32], acc: &mut [f64]| {
            let hi = lo + rows.len();
            let mut lbuf: Vec<f32> = (lo..hi).map(|g| lref.row(g)[0]).collect();
            for (i, r) in rows.iter_mut().enumerate() {
                if *r < lbuf[i] {
                    lbuf[i] = *r;
                    acc[0] += 1.0;
                } else {
                    *r = lbuf[i];
                }
            }
            unsafe { lref.write_rows_unsync(lo, hi, &lbuf) };
        });
        let r = {
            let pass =
                StreamPass::<MinSelect>::new().forward_with(&x, OutputSink::Mem(&x_next), 1, hook);
            exec::run_pass_ring(src, &pass, &cfg.spmm)?
        };
        bytes_read += r.stats.bytes_read;
        let delta = r.accs[0][0] as u64;
        iters += 1;
        if delta == 0 {
            converged = true;
            break;
        }
        changed.push(delta);
        std::mem::swap(&mut x, &mut x_next);
    }

    let labels: Vec<u32> = (0..n).map(|i| label.row(i)[0] as u32).collect();
    let components = labels
        .iter()
        .enumerate()
        .filter(|&(v, &l)| l as usize == v)
        .count();
    Ok((
        labels,
        LabelPropStats {
            secs: sw.secs(),
            iters,
            converged,
            components,
            changed,
            bytes_read,
        },
    ))
}

/// Union-find reference: smallest vertex id per connected component of
/// the **undirected** graph underlying the edge list (test oracle).
pub fn cc_ref(num_verts: usize, edges: &[(u32, u32)]) -> Vec<u32> {
    let mut parent: Vec<u32> = (0..num_verts as u32).collect();
    fn find(parent: &mut [u32], v: u32) -> u32 {
        let mut r = v;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = v;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for &(a, b) in edges {
        let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
        if ra != rb {
            // Union by smaller id, so every root is its component minimum.
            let (lo, hi) = (ra.min(rb), ra.max(rb));
            parent[hi as usize] = lo;
        }
    }
    (0..num_verts as u32)
        .map(|v| find(&mut parent, v))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::{rmat, sbm, EdgeList};
    use crate::io::{ShardedStore, StoreSpec};
    use crate::spmm::SemSource;
    use std::collections::HashSet;
    use std::sync::Arc;

    fn image(el: &EdgeList, tile: usize, fmt: TileFormat) -> Arc<TiledImage> {
        let m = Csr::from_edgelist(el);
        Arc::new(TiledImage::build(&m, tile, fmt))
    }

    #[test]
    fn matches_union_find_on_symmetrized_rmat() {
        // RMAT leaves plenty of isolated vertices at this density —
        // exactly the singleton components that must keep their own id.
        let mut el = rmat::generate(9, 1200, rmat::RmatParams::default(), 47);
        el.symmetrize();
        let want = cc_ref(el.num_verts, &el.edges);
        for fmt in [TileFormat::Scsr, TileFormat::Dcsc] {
            let img = image(&el, 128, fmt);
            let cfg = LabelPropConfig {
                spmm: SpmmOpts {
                    threads: 3,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (labels, stats) = connected_components(&Source::Mem(img), &cfg).unwrap();
            assert!(stats.converged, "{fmt:?}");
            assert_eq!(labels, want, "{fmt:?}");
            assert_eq!(
                stats.components,
                want.iter().collect::<HashSet<_>>().len()
            );
        }
    }

    #[test]
    fn sem_run_matches_and_pure_clusters_are_components() {
        // in_out = ∞ keeps every edge inside its cluster, so components
        // can only merge within clusters — labels must respect cluster
        // boundaries, and the SEM run must equal the IM run bit for bit.
        let mut el = sbm::generate(
            sbm::SbmParams {
                num_verts: 400,
                num_edges: 4000,
                num_clusters: 4,
                in_out: f64::INFINITY,
                clustered_order: true,
            },
            13,
        );
        el.dedup();
        let want = cc_ref(el.num_verts, &el.edges);
        let img = image(&el, 64, TileFormat::Scsr);
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        store.put("cc.semm", &buf).unwrap();
        let sem = Source::Sem(SemSource::open(&store, "cc.semm").unwrap());
        let cfg = LabelPropConfig {
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (l_mem, _) = connected_components(&Source::Mem(img), &cfg).unwrap();
        let (l_sem, stats) = connected_components(&sem, &cfg).unwrap();
        assert_eq!(l_mem, l_sem, "SEM must match IM bit for bit");
        assert_eq!(l_sem, want);
        assert!(stats.bytes_read > 0, "SEM run must stream the matrix");
        // Cluster purity: labels never cross the 100-vertex cluster
        // boundaries in_out = ∞ guarantees.
        for (v, &l) in l_sem.iter().enumerate() {
            assert_eq!(v / 100, l as usize / 100, "vertex {v} labeled {l}");
        }
    }

    #[test]
    fn warm_start_refreshes_after_insertions_in_fewer_sweeps() {
        // Two long chains; a fresh edge bridges them. Warm-starting from
        // the pre-insert labeling must converge to the merged components
        // in far fewer sweeps than relabeling from scratch (only the
        // absorbed chain's labels move).
        let half = 40u32;
        let mut el = EdgeList::new(2 * half as usize);
        for v in 0..half - 1 {
            el.edges.push((v, v + 1));
            el.edges.push((half + v, half + v + 1));
        }
        el.symmetrize();
        let img = image(&el, 16, TileFormat::Scsr);
        let cfg = LabelPropConfig {
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let (old, _) = connected_components(&Source::Mem(img), &cfg).unwrap();
        assert_eq!(old[half as usize], half, "two components before the edit");
        // Insert a bridge at the END of chain A: cold relabeling now
        // floods label 0 across both chains (~2·half sweeps); the warm
        // restart only reflows the absorbed chain (~half sweeps).
        el.edges.push((half - 1, half));
        el.symmetrize();
        let img = image(&el, 16, TileFormat::Scsr);
        let (cold, cold_stats) =
            connected_components(&Source::Mem(img.clone()), &cfg).unwrap();
        let (warm, warm_stats) =
            connected_components_warm(&Source::Mem(img.clone()), Some(&old), &cfg).unwrap();
        assert_eq!(warm, cold, "warm refresh must reach the same fixpoint");
        assert_eq!(warm, cc_ref(el.num_verts, &el.edges));
        assert!(warm_stats.converged);
        assert!(
            warm_stats.iters < cold_stats.iters,
            "warm {} vs cold {} sweeps",
            warm_stats.iters,
            cold_stats.iters
        );
        // Malformed warm labelings are rejected, not propagated.
        assert!(
            connected_components_warm(&Source::Mem(img.clone()), Some(&old[1..]), &cfg)
                .is_err()
        );
        let bogus = vec![9999u32; el.num_verts];
        assert!(
            connected_components_warm(&Source::Mem(img), Some(&bogus), &cfg).is_err()
        );
    }

    #[test]
    fn chain_converges_in_diameter_sweeps_and_cap_truncates() {
        // An undirected path 0–1–…–63: label 0 floods one hop per sweep.
        let mut el = EdgeList::new(64);
        for v in 0..63u32 {
            el.edges.push((v, v + 1));
        }
        el.symmetrize();
        let img = image(&el, 16, TileFormat::Scsr);
        let cfg = LabelPropConfig {
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let (labels, stats) = connected_components(&Source::Mem(img.clone()), &cfg).unwrap();
        assert!(labels.iter().all(|&l| l == 0));
        assert_eq!(stats.components, 1);
        // 63 flooding sweeps + the fixpoint-confirming sweep.
        assert_eq!(stats.iters, 64);
        // A capped run reports non-convergence and partial labels.
        let capped = LabelPropConfig {
            max_iters: 3,
            spmm: SpmmOpts::sequential(),
        };
        let (lp, sp) = connected_components(&Source::Mem(img), &capped).unwrap();
        assert!(!sp.converged);
        assert_eq!(lp[3], 0, "within the flooded horizon");
        assert_eq!(lp[40], 37, "beyond it: min label within 3 hops");
    }
}
