//! Frontier-based BFS as or-and semiring sweeps of the SEM store.
//!
//! One level of BFS is one streaming pass: under the boolean semiring
//! [`OrAnd`], `y = A ⊗ x` maps a frontier indicator vector `x` to the
//! indicator of its out-neighborhood — `y[v] = ⋁ᵤ (A[v][u] ∧ x[u])`,
//! using the same tile kernels, prefetch, scheduling, and tile-row cache
//! as every arithmetic multiply (the image convention matches
//! [`super::pagerank`]: `A[dst][src] = 1` for an edge `src → dst`, so the
//! sweep expands along edge direction). A fused [`RowHook`] then masks
//! the expansion against the visited set *while the rows are hot*: newly
//! reached vertices get their level recorded and form the next frontier
//! in place, already in the pass's output vector — a BFS level costs one
//! matrix sweep and zero extra vector sweeps.
//!
//! The sparse matrix never leaves the store (SEM mode): BFS on a graph
//! much larger than memory needs only three n×1 vectors plus the visited
//! and level vectors in RAM.

use crate::metrics::Stopwatch;
use crate::matrix::NumaDense;
use crate::spmm::{
    engine, exec, MinPlus, OrAnd, OutputSink, RowHook, Source, SpmmOpts, StreamPass,
};
use anyhow::{bail, Result};

/// BFS configuration.
#[derive(Debug, Clone)]
pub struct BfsConfig {
    /// Stop after this many levels even if frontiers remain (the
    /// default never truncates — BFS ends when a frontier is empty).
    pub max_levels: usize,
    /// Engine options for each sweep.
    pub spmm: SpmmOpts,
}

impl Default for BfsConfig {
    fn default() -> Self {
        BfsConfig {
            max_levels: usize::MAX,
            spmm: SpmmOpts::default(),
        }
    }
}

/// Run statistics.
#[derive(Debug, Clone, Default)]
pub struct BfsStats {
    /// Wall-clock seconds of the whole traversal.
    pub secs: f64,
    /// Deepest level assigned (= number of non-empty expansion sweeps).
    pub levels: usize,
    /// Vertices reached, including the root.
    pub reached: u64,
    /// Newly reached vertices per level, starting at level 1.
    pub frontier: Vec<u64>,
    /// Logical sparse-matrix bytes read from the store across all sweeps
    /// (SEM mode; 0 for IM).
    pub bytes_read: u64,
}

/// Breadth-first search from `root` over an adjacency image
/// (`row = dst`, `col = src`). Returns per-vertex levels (`-1` =
/// unreached, root = 0) and run statistics.
pub fn bfs(src: &Source, root: u32, cfg: &BfsConfig) -> Result<(Vec<i32>, BfsStats)> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n {
        bail!("bfs needs a square adjacency image");
    }
    if root as usize >= n {
        bail!("bfs root {root} out of range (n = {n})");
    }
    let sw = Stopwatch::start();
    let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
    let mut x = NumaDense::zeros(n, 1, ncfg);
    let mut x_next = NumaDense::zeros(n, 1, ncfg);
    let mut visited = NumaDense::zeros(n, 1, ncfg);
    let mut levels = NumaDense::zeros(n, 1, ncfg);
    levels.fill(-1.0);
    levels.row_mut(root as usize)[0] = 0.0;
    visited.row_mut(root as usize)[0] = 1.0;
    x.row_mut(root as usize)[0] = 1.0;

    let mut level = 0usize;
    let mut reached = 1u64;
    let mut frontier = Vec::new();
    let mut bytes_read = 0u64;
    while level < cfg.max_levels {
        let d = (level + 1) as f32;
        let vis = &visited;
        let lev = &levels;
        // The hook sees each finalized interval of y = A ⊗ x exactly
        // once: unvisited hits become level-d vertices and stay 1.0 in
        // the outgoing rows (the next frontier); everything else is
        // masked to 0. Intervals are disjoint, so the unsynchronized
        // writes never race.
        let hook: RowHook = Box::new(move |lo: usize, rows: &mut [f32], acc: &mut [f64]| {
            let hi = lo + rows.len();
            let mut vbuf: Vec<f32> = (lo..hi).map(|g| vis.row(g)[0]).collect();
            let mut lbuf: Vec<f32> = (lo..hi).map(|g| lev.row(g)[0]).collect();
            for (i, r) in rows.iter_mut().enumerate() {
                if *r != 0.0 && vbuf[i] == 0.0 {
                    vbuf[i] = 1.0;
                    lbuf[i] = d;
                    acc[0] += 1.0;
                    *r = 1.0;
                } else {
                    *r = 0.0;
                }
            }
            unsafe {
                vis.write_rows_unsync(lo, hi, &vbuf);
                lev.write_rows_unsync(lo, hi, &lbuf);
            }
        });
        let r = {
            let pass =
                StreamPass::<OrAnd>::new().forward_with(&x, OutputSink::Mem(&x_next), 1, hook);
            exec::run_pass_ring(src, &pass, &cfg.spmm)?
        };
        bytes_read += r.stats.bytes_read;
        let newly = r.accs[0][0] as u64;
        if newly == 0 {
            break;
        }
        level += 1;
        reached += newly;
        frontier.push(newly);
        std::mem::swap(&mut x, &mut x_next);
    }

    let out: Vec<i32> = (0..n).map(|i| levels.row(i)[0] as i32).collect();
    Ok((
        out,
        BfsStats {
            secs: sw.secs(),
            levels: level,
            reached,
            frontier,
            bytes_read,
        },
    ))
}

/// Refresh a previous BFS labeling after **edge insertions** — the
/// incremental hook for the delta layer. Old levels stay valid upper
/// bounds (every old path still exists), so unit-weight min-plus
/// relaxation seeded from them converges to the exact new levels,
/// usually in a couple of sweeps instead of re-flooding depth-many from
/// the root. `prev` must come from a BFS at the same `root` over a
/// subgraph of the current image; **deletions** break the upper-bound
/// property — rerun [`bfs`] from scratch after removing edges.
///
/// In the returned stats, `levels` counts relaxation sweeps (including
/// the fixpoint-confirming one) and `frontier` the levels improved per
/// sweep; `cfg.max_levels` caps the sweeps.
pub fn bfs_refresh(
    src: &Source,
    root: u32,
    prev: &[i32],
    cfg: &BfsConfig,
) -> Result<(Vec<i32>, BfsStats)> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n {
        bail!("bfs needs a square adjacency image");
    }
    if root as usize >= n {
        bail!("bfs root {root} out of range (n = {n})");
    }
    if prev.len() != n {
        bail!("previous levels have {} entries for {n} vertices", prev.len());
    }
    if prev[root as usize] != 0 {
        bail!("previous levels do not come from a BFS rooted at {root}");
    }
    let sw = Stopwatch::start();
    let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
    let mut x = NumaDense::zeros(n, 1, ncfg);
    let mut x_next = NumaDense::zeros(n, 1, ncfg);
    let mut dist = NumaDense::zeros(n, 1, ncfg);
    for v in 0..n {
        let d = if prev[v] < 0 {
            f32::INFINITY
        } else {
            prev[v] as f32
        };
        x.row_mut(v)[0] = d;
        dist.row_mut(v)[0] = d;
    }

    let mut sweeps = 0usize;
    let mut improved = Vec::new();
    let mut bytes_read = 0u64;
    while sweeps < cfg.max_levels {
        let dref = &dist;
        // dist' = min(dist, min-plus expansion): a binary adjacency
        // image weighs every edge 1, so the relaxation fixpoint is the
        // exact hop count. Intervals are disjoint — see the bfs hook.
        let hook: RowHook = Box::new(move |lo: usize, rows: &mut [f32], acc: &mut [f64]| {
            let hi = lo + rows.len();
            let mut dbuf: Vec<f32> = (lo..hi).map(|g| dref.row(g)[0]).collect();
            for (i, r) in rows.iter_mut().enumerate() {
                if *r < dbuf[i] {
                    dbuf[i] = *r;
                    acc[0] += 1.0;
                } else {
                    *r = dbuf[i];
                }
            }
            unsafe { dref.write_rows_unsync(lo, hi, &dbuf) };
        });
        let r = {
            let pass = StreamPass::<MinPlus>::new()
                .forward_with(&x, OutputSink::Mem(&x_next), 1, hook);
            exec::run_pass_ring(src, &pass, &cfg.spmm)?
        };
        bytes_read += r.stats.bytes_read;
        sweeps += 1;
        let delta = r.accs[0][0] as u64;
        if delta == 0 {
            break;
        }
        improved.push(delta);
        std::mem::swap(&mut x, &mut x_next);
    }

    let mut reached = 0u64;
    let out: Vec<i32> = (0..n)
        .map(|i| {
            let d = dist.row(i)[0];
            if d.is_finite() {
                reached += 1;
                d as i32
            } else {
                -1
            }
        })
        .collect();
    Ok((
        out,
        BfsStats {
            secs: sw.secs(),
            levels: sweeps,
            reached,
            frontier: improved,
            bytes_read,
        },
    ))
}

/// Queue-based reference BFS over an edge list (test oracle). An edge
/// tuple `(r, c)` is the matrix entry `A[r][c]`, i.e. the directed edge
/// `c → r`, matching the image convention.
pub fn bfs_ref(num_verts: usize, edges: &[(u32, u32)], root: u32) -> Vec<i32> {
    let mut adj = vec![Vec::new(); num_verts];
    for &(r, c) in edges {
        adj[c as usize].push(r);
    }
    let mut lv = vec![-1i32; num_verts];
    lv[root as usize] = 0;
    let mut q = std::collections::VecDeque::new();
    q.push_back(root);
    while let Some(u) = q.pop_front() {
        let next = lv[u as usize] + 1;
        for &v in &adj[u as usize] {
            if lv[v as usize] < 0 {
                lv[v as usize] = next;
                q.push_back(v);
            }
        }
    }
    lv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::{rmat, sbm};
    use crate::io::{ShardedStore, StoreSpec};
    use crate::spmm::SemSource;
    use std::sync::Arc;

    fn image(el: &crate::graph::EdgeList, tile: usize, fmt: TileFormat) -> Arc<TiledImage> {
        let m = Csr::from_edgelist(el);
        Arc::new(TiledImage::build(&m, tile, fmt))
    }

    #[test]
    fn matches_reference_on_rmat_both_formats() {
        let el = rmat::generate(9, 4000, rmat::RmatParams::default(), 31);
        let want = bfs_ref(el.num_verts, &el.edges, 0);
        for fmt in [TileFormat::Scsr, TileFormat::Dcsc] {
            let img = image(&el, 128, fmt);
            let cfg = BfsConfig {
                spmm: SpmmOpts {
                    threads: 3,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (lv, stats) = bfs(&Source::Mem(img), 0, &cfg).unwrap();
            assert_eq!(lv, want, "{fmt:?}");
            assert_eq!(
                stats.reached,
                want.iter().filter(|&&l| l >= 0).count() as u64
            );
            assert_eq!(
                stats.levels as i32,
                *want.iter().max().unwrap(),
                "deepest level"
            );
            assert_eq!(
                stats.frontier.iter().sum::<u64>() + 1,
                stats.reached,
                "frontiers partition the reached set"
            );
        }
    }

    #[test]
    fn sem_traversal_is_identical_and_streams_the_matrix() {
        let mut el = sbm::generate(
            sbm::SbmParams {
                num_verts: 500,
                num_edges: 3000,
                num_clusters: 4,
                in_out: 4.0,
                clustered_order: true,
            },
            7,
        );
        el.dedup();
        let img = image(&el, 64, TileFormat::Scsr);
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        store.put("bfs.semm", &buf).unwrap();
        let sem = Source::Sem(SemSource::open(&store, "bfs.semm").unwrap());
        let cfg = BfsConfig {
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (lv_mem, _) = bfs(&Source::Mem(img), 3, &cfg).unwrap();
        let (lv_sem, stats) = bfs(&sem, 3, &cfg).unwrap();
        assert_eq!(lv_mem, lv_sem, "SEM must match IM bit for bit");
        assert_eq!(lv_sem, bfs_ref(el.num_verts, &el.edges, 3));
        assert!(stats.bytes_read > 0, "SEM BFS must stream the matrix");
    }

    #[test]
    fn max_levels_truncates_the_traversal() {
        let el = rmat::generate(8, 1500, rmat::RmatParams::default(), 11);
        let img = image(&el, 128, TileFormat::Scsr);
        let want = bfs_ref(el.num_verts, &el.edges, 0);
        let cfg = BfsConfig {
            max_levels: 2,
            spmm: SpmmOpts::sequential(),
        };
        let (lv, stats) = bfs(&Source::Mem(img), 0, &cfg).unwrap();
        assert!(stats.levels <= 2);
        for (v, (&got, &exp)) in lv.iter().zip(&want).enumerate() {
            if (0..=2).contains(&exp) {
                assert_eq!(got, exp, "vertex {v} within the horizon");
            } else {
                assert_eq!(got, -1, "vertex {v} beyond the horizon");
            }
        }
    }

    #[test]
    fn refresh_after_insertion_matches_cold_bfs_in_fewer_sweeps() {
        // A directed chain 0→1→…→63, then a shortcut 0→62 near the end:
        // the cold traversal still floods ~depth levels, but relaxing
        // from the old labeling touches only the two improved vertices.
        let mut el = crate::graph::EdgeList::new(64);
        for v in 0..63u32 {
            el.edges.push((v + 1, v)); // tuple (dst, src): edge v → v+1
        }
        let cfg = BfsConfig {
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let img = image(&el, 16, TileFormat::Scsr);
        let (old, _) = bfs(&Source::Mem(img), 0, &cfg).unwrap();
        el.edges.push((62, 0)); // shortcut 0 → 62
        let img = image(&el, 16, TileFormat::Scsr);
        let (cold, cold_stats) = bfs(&Source::Mem(img.clone()), 0, &cfg).unwrap();
        let (warm, warm_stats) =
            bfs_refresh(&Source::Mem(img.clone()), 0, &old, &cfg).unwrap();
        assert_eq!(warm, cold, "refresh must reach the exact new levels");
        assert_eq!(warm, bfs_ref(el.num_verts, &el.edges, 0));
        assert_eq!(warm[62], 1);
        assert_eq!(warm[63], 2);
        assert_eq!(warm_stats.reached, cold_stats.reached);
        assert!(
            warm_stats.levels < cold_stats.levels,
            "refresh took {} sweeps vs {} cold levels",
            warm_stats.levels,
            cold_stats.levels
        );
        // Malformed previous labelings are rejected.
        assert!(bfs_refresh(&Source::Mem(img.clone()), 0, &old[1..], &cfg).is_err());
        assert!(
            bfs_refresh(&Source::Mem(img), 5, &old, &cfg).is_err(),
            "prev must be rooted at the requested root"
        );
    }

    #[test]
    fn disconnected_vertices_stay_unreached() {
        // A ring 0..32 plus isolated vertices 32..64.
        let mut el = crate::graph::EdgeList::new(64);
        for v in 0..32u32 {
            el.edges.push(((v + 1) % 32, v));
        }
        let img = image(&el, 16, TileFormat::Scsr);
        let (lv, stats) = bfs(
            &Source::Mem(img),
            0,
            &BfsConfig {
                spmm: SpmmOpts::sequential(),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(stats.reached, 32);
        assert_eq!(stats.levels, 31, "a directed ring is a single chain");
        for v in 0..64 {
            assert_eq!(lv[v], if v < 32 { v as i32 } else { -1 });
        }
    }
}
