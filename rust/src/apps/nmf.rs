//! Non-negative matrix factorization over SEM-SpMM (§4.3, Fig 16).
//!
//! Lee–Seung multiplicative updates for `A ≈ W H` with A an n×n sparse
//! adjacency matrix, W (n×k) and H (k×n). H is held transposed (Hᵀ, n×k)
//! so both factors are tall-skinny and both updates take the same form:
//!
//! ```text
//! P  = Aᵀ W            (SEM-SpMM)        Hᵀ ← Hᵀ ∘ P ⊘ (Hᵀ·WᵀW + ε)
//! Q  = A Hᵀ            (SEM-SpMM)        W  ← W  ∘ Q ⊘ (W·HHᵀ + ε)
//! ```
//!
//! The factors can be as large as the sparse matrix, so W and Hᵀ are
//! stored as column panels of `cols_in_mem` columns ([`super::TallPanels`];
//! Fig 16's memory knob). With panels narrower than k, the denominator
//! `W·HHᵀ` needs every panel of W per output panel — the vertical-
//! partitioning locality loss the paper measures (Fig 11 Vert-part).
//!
//! The fused elementwise update runs natively or through the AOT PJRT
//! artifact (`nmf_w_k*` — the L1 Pallas kernel) when the full factor is
//! memory-resident and k is a supported artifact shape.

use super::TallPanels;
use crate::io::{CacheUsage, ShardedStore};
use crate::matrix::{ops, DenseMatrix};
use crate::metrics::Stopwatch;
use crate::runtime::DenseBackend;
use crate::spmm::{engine, Source, SpmmOpts};
use anyhow::{bail, Result};
use std::sync::Arc;

const EPS: f32 = 1e-9;

/// NMF configuration.
#[derive(Debug, Clone)]
pub struct NmfConfig {
    /// Factorization rank.
    pub k: usize,
    pub iterations: usize,
    /// Factor columns kept in memory (panel width; must divide k).
    /// `cols_in_mem == k` keeps the factors fully in memory.
    pub cols_in_mem: usize,
    pub spmm: SpmmOpts,
    /// Offload the fused update to a dense backend (the PJRT artifacts
    /// when built with `--features pjrt` + `make artifacts`, or the
    /// native backend) when possible.
    pub backend: Option<Arc<dyn DenseBackend>>,
    pub seed: u64,
}

impl Default for NmfConfig {
    fn default() -> Self {
        NmfConfig {
            k: 16,
            iterations: 10,
            cols_in_mem: 16,
            spmm: SpmmOpts::default(),
            backend: None,
            seed: 0x17F,
        }
    }
}

/// Per-run result.
#[derive(Debug)]
pub struct NmfResult {
    /// ‖A − WH‖_F after each iteration.
    pub residuals: Vec<f64>,
    /// Wall-clock seconds of each iteration.
    pub secs_per_iter: Vec<f64>,
    /// Wall-clock seconds of the whole run.
    pub secs: f64,
    /// Logical bytes read at the array interface.
    pub bytes_read: u64,
    /// Logical bytes written at the array interface.
    pub bytes_written: u64,
    /// Combined tile-row cache activity of the A and Aᵀ sources (each
    /// iteration multiplies by both; with a cache budget covering both
    /// images, iterations after the first read nothing from the store).
    pub cache: Option<CacheUsage>,
    /// The W factor, as stored panels.
    pub w: TallPanels,
    /// The Hᵀ factor, as stored panels.
    pub ht: TallPanels,
}

/// Run NMF. `src_a` is the adjacency image, `src_at` its transpose image,
/// `nnz` the number of non-zeros (for the residual).
pub fn nmf(
    src_a: &Source,
    src_at: &Source,
    store: &Arc<ShardedStore>,
    cfg: &NmfConfig,
) -> Result<NmfResult> {
    let n = src_a.meta().nrows;
    if src_a.meta().ncols != n || src_at.meta().nrows != n || src_at.meta().ncols != n {
        bail!("nmf needs square A and Aᵀ images of equal size");
    }
    let k = cfg.k;
    let w_cols = cfg.cols_in_mem;
    if w_cols == 0 || k % w_cols != 0 {
        bail!("cols_in_mem ({w_cols}) must divide k ({k})");
    }
    let np = k / w_cols;
    let in_mem = np == 1;
    let nnz = src_a.meta().nnz as f64;

    let read0 = store.stats.bytes_read.get();
    let written0 = store.stats.bytes_written.get();
    // Resolve both sources' caches up front, so the baselines and the
    // final readings come from the same caches across budget changes.
    let caches: Vec<_> = [src_a, src_at]
        .iter()
        .filter_map(|s| s.resolve_tile_cache(&cfg.spmm))
        .collect();
    let cache0 = caches
        .iter()
        .map(|c| c.usage())
        .fold(CacheUsage::default(), |acc, u| acc.plus(&u));
    let sw = Stopwatch::start();

    let mut w = TallPanels::create(store, "nmf.W", n, w_cols, np, in_mem)?;
    let mut ht = TallPanels::create(store, "nmf.Ht", n, w_cols, np, in_mem)?;
    {
        // Initialize from a full-width random factor sliced into panels so
        // the starting point (and hence the whole trajectory) is identical
        // for every `cols_in_mem` setting.
        let w0 = DenseMatrix::random(n, k, cfg.seed);
        let h0 = DenseMatrix::random(n, k, cfg.seed ^ 0x8000);
        for q in 0..np {
            w.store(q, &w0.col_slice(q * w_cols, (q + 1) * w_cols))?;
            ht.store(q, &h0.col_slice(q * w_cols, (q + 1) * w_cols))?;
        }
    }

    let mut residuals = Vec::with_capacity(cfg.iterations);
    let mut secs_per_iter = Vec::with_capacity(cfg.iterations);
    for _it in 0..cfg.iterations {
        let isw = Stopwatch::start();
        // --- H-side update: P = Aᵀ W; Hᵀ ← Hᵀ ∘ P ⊘ (Hᵀ WᵀW + ε).
        let wtw = panels_gram(&w)?;
        update_factor(src_at, &w, &mut ht, &wtw, cfg)?;

        // --- W-side update: Q = A Hᵀ; W ← W ∘ Q ⊘ (W HHᵀ + ε).
        let hht = panels_gram(&ht)?;
        update_factor(src_a, &ht, &mut w, &hht, cfg)?;

        // --- Residual: ‖A − WH‖² = nnz − 2⟨AᵀW, Hᵀ⟩ + ⟨WᵀW, HHᵀ⟩.
        let wtw = panels_gram(&w)?;
        let hht = panels_gram(&ht)?;
        let mut inner = 0f64; // ⟨Aᵀ W, Hᵀ⟩
        for q in 0..np {
            let wq = w.load(q)?;
            let (pq, _) = engine::spmm_out(src_at, &wq, &cfg.spmm)?;
            let hq = ht.load(q)?;
            inner += ops::dot(&pq, &hq);
        }
        let frob_term: f64 = wtw
            .data
            .iter()
            .zip(&hht.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let sq = (nnz - 2.0 * inner + frob_term).max(0.0);
        residuals.push(sq.sqrt());
        secs_per_iter.push(isw.secs());
    }

    let cache = if caches.is_empty() {
        None
    } else {
        Some(
            caches
                .iter()
                .map(|c| c.usage())
                .fold(CacheUsage::default(), |acc, u| acc.plus(&u))
                .since(&cache0),
        )
    };
    Ok(NmfResult {
        residuals,
        secs_per_iter,
        secs: sw.secs(),
        bytes_read: store.stats.bytes_read.get() - read0,
        bytes_written: store.stats.bytes_written.get() - written0,
        cache,
        w,
        ht,
    })
}

/// Gram matrix of a panel-stored tall factor (k×k), accumulating panel
/// cross-terms two panels at a time.
fn panels_gram(x: &TallPanels) -> Result<DenseMatrix> {
    let b = x.panel_cols();
    let k = b * x.num_panels();
    let mut g = DenseMatrix::zeros(k, k);
    for q in 0..x.num_panels() {
        let xq = x.load(q)?;
        for r in q..x.num_panels() {
            let blk = if r == q {
                ops::gram(&xq)
            } else {
                let xr = x.load(r)?;
                ops::xty(&xq, &xr)
            };
            for i in 0..b {
                for j in 0..b {
                    g.set(q * b + i, r * b + j, blk.get(i, j));
                    g.set(r * b + j, q * b + i, blk.get(i, j));
                }
            }
        }
    }
    Ok(g)
}

/// One multiplicative update of `target` (tall n×k in panels):
/// `target ← target ∘ (M · other) ⊘ (target · G + ε)` where `M` is the
/// sparse image, `other` the opposite factor, and `G` its Gram matrix.
fn update_factor(
    msrc: &Source,
    other: &TallPanels,
    target: &mut TallPanels,
    g: &DenseMatrix,
    cfg: &NmfConfig,
) -> Result<()> {
    let b = target.panel_cols();
    let np = target.num_panels();
    let k = b * np;

    // Fast path: fully in memory, supported k → fused (backend or the
    // open-coded native update).
    if np == 1 {
        let t = target.load(0)?;
        let o = other.load(0)?;
        let (num, _) = engine::spmm_out(msrc, &o, &cfg.spmm)?;
        let updated = match &cfg.backend {
            Some(be) if be.supports_k(k) => be.nmf_update_w(&t, &num, g)?,
            _ => fused_update_native(&t, &num, g),
        };
        target.store(0, &updated)?;
        return Ok(());
    }

    // Panelized path: numerator per panel is independent; the denominator
    // needs every panel of `target` (vertical-partitioning locality loss).
    let mut new_panels = Vec::with_capacity(np);
    for q in 0..np {
        let oq = other.load(q)?;
        let (num_q, _) = engine::spmm_out(msrc, &oq, &cfg.spmm)?;
        // D_q = Σ_r target_r · G[rb.., qb..]
        let mut denom = DenseMatrix::zeros(target.nrows(), b);
        for r in 0..np {
            let tr = target.load(r)?;
            let mut gblk = DenseMatrix::zeros(b, b);
            for i in 0..b {
                for j in 0..b {
                    gblk.set(i, j, g.get(r * b + i, q * b + j));
                }
            }
            ops::axpy(&mut denom, 1.0, &ops::mul_small(&tr, &gblk));
        }
        let tq = target.load(q)?;
        let mut out = DenseMatrix::zeros(target.nrows(), b);
        for i in 0..out.data.len() {
            out.data[i] = tq.data[i] * num_q.data[i] / (denom.data[i] + EPS);
        }
        new_panels.push(out);
    }
    for (q, p) in new_panels.into_iter().enumerate() {
        target.store(q, &p)?;
    }
    Ok(())
}

/// Native fused update: `t ∘ num ⊘ (t · G + ε)`.
fn fused_update_native(t: &DenseMatrix, num: &DenseMatrix, g: &DenseMatrix) -> DenseMatrix {
    let denom = ops::mul_small(t, g);
    let mut out = DenseMatrix::zeros(t.nrows, t.ncols);
    for i in 0..out.data.len() {
        out.data[i] = t.data[i] * num.data[i] / (denom.data[i] + EPS);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::rmat;
    use crate::io::StoreSpec;

    fn setup(scale: u32, edges: usize) -> (Arc<TiledImage>, Arc<TiledImage>, usize) {
        let el = rmat::generate(scale, edges, rmat::RmatParams::default(), 31);
        let m = Csr::from_edgelist(&el);
        let mt = m.transpose();
        (
            Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr)),
            Arc::new(TiledImage::build(&mt, 128, TileFormat::Scsr)),
            m.nnz(),
        )
    }

    #[test]
    fn residual_decreases() {
        let (a, at, _) = setup(8, 2000);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 8,
            iterations: 6,
            cols_in_mem: 8,
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let res = nmf(&Source::Mem(a), &Source::Mem(at), &store, &cfg).unwrap();
        assert_eq!(res.residuals.len(), 6);
        for w in res.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * 1.001,
                "residual must not increase: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn panelized_matches_full_memory() {
        let (a, at, _) = setup(7, 900);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let run = |cols: usize| {
            let cfg = NmfConfig {
                k: 4,
                iterations: 4,
                cols_in_mem: cols,
                spmm: SpmmOpts::sequential(),
                ..Default::default()
            };
            nmf(&Source::Mem(a.clone()), &Source::Mem(at.clone()), &store, &cfg)
                .unwrap()
                .residuals
        };
        let full = run(4);
        let panel2 = run(2);
        let panel1 = run(1);
        for i in 0..full.len() {
            assert!(
                (full[i] - panel2[i]).abs() < 1e-2 * full[i].max(1.0),
                "iter {i}: {} vs {}",
                full[i],
                panel2[i]
            );
            assert!((full[i] - panel1[i]).abs() < 1e-2 * full[i].max(1.0));
        }
    }

    #[test]
    fn panelized_run_touches_store() {
        let (a, at, _) = setup(7, 800);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 4,
            iterations: 2,
            cols_in_mem: 2,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let res = nmf(&Source::Mem(a), &Source::Mem(at), &store, &cfg).unwrap();
        assert!(res.bytes_read > 0 && res.bytes_written > 0);
    }

    #[test]
    fn backend_fused_update_matches_native() {
        // The PJRT backend when artifacts are built, the native backend
        // otherwise — either must reproduce the open-coded update.
        let be = crate::runtime::backend_from_env()
            .unwrap_or_else(crate::runtime::default_backend);
        let (a, at, _) = setup(7, 900);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let base = NmfConfig {
            k: 16,
            iterations: 3,
            cols_in_mem: 16,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let plain = nmf(&Source::Mem(a.clone()), &Source::Mem(at.clone()), &store, &base)
            .unwrap()
            .residuals;
        let be_cfg = NmfConfig {
            backend: Some(be),
            ..base
        };
        let offloaded = nmf(&Source::Mem(a), &Source::Mem(at), &store, &be_cfg)
            .unwrap()
            .residuals;
        for (n, x) in plain.iter().zip(&offloaded) {
            assert!(
                (n - x).abs() < 1e-2 * n.max(1.0),
                "plain {n} vs backend {x}"
            );
        }
    }

    #[test]
    fn invalid_panel_width_rejected() {
        let (a, at, _) = setup(6, 300);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 16,
            cols_in_mem: 3,
            ..Default::default()
        };
        assert!(nmf(&Source::Mem(a), &Source::Mem(at), &store, &cfg).is_err());
    }
}
