//! Non-negative matrix factorization over SEM-SpMM (§4.3, Fig 16) —
//! fused single-image edition.
//!
//! Lee–Seung multiplicative updates for `A ≈ W H` with A an n×n sparse
//! adjacency matrix, W (n×k) and H (k×n). H is held transposed (Hᵀ, n×k)
//! so both factors are tall-skinny and both updates take the same form:
//!
//! ```text
//! P  = Aᵀ W            Hᵀ ← Hᵀ ∘ P ⊘ (Hᵀ·WᵀW + ε)
//! Q  = A Hᵀ            W  ← W  ∘ Q ⊘ (W·HHᵀ + ε)
//! ```
//!
//! **One sweep, both products.** Earlier revisions kept a second full
//! transpose image `Aᵀ` on the store and streamed *three* sparse images
//! per iteration (Aᵀ for the H update, A for the W update, Aᵀ again for
//! the residual). This edition keeps only A: a fused
//! [`crate::spmm::StreamPass`] computes `Q = A·Hᵀ` (forward gather) and
//! `P = Aᵀ·W` (transpose scatter) from the *same* tile bytes in one
//! streaming sweep, and folds the residual inner product `⟨P, Hᵀ⟩` into
//! the pass as a reduce-time hook — the on-store sparse footprint halves
//! and per-iteration sparse I/O drops to one pass (vs. three).
//!
//! Both updates therefore read the **iteration-entry factors** (the
//! classic "simultaneous" multiplicative-update variant, vs. the old
//! Gauss–Seidel ordering where the W update saw the fresh Hᵀ — both are
//! standard Lee–Seung schemes; `NmfConfig::fused = false` runs the exact
//! same math as two separate single-op sweeps, which the `fused_ops`
//! bench experiment uses as its I/O baseline). `residuals[t]` is
//! ‖A − W H‖_F of the factors *entering* iteration `t`, which the pass
//! computes for free; the old post-update residual cost an extra Aᵀ
//! stream per iteration.
//!
//! The factors can be as large as the sparse matrix, so W and Hᵀ are
//! stored as column panels of `cols_in_mem` columns ([`super::TallPanels`];
//! Fig 16's memory knob). With panels narrower than k, the denominator
//! `W·HHᵀ` needs every panel of W per output panel — the vertical-
//! partitioning locality loss the paper measures (Fig 11 Vert-part) —
//! and each iteration runs one fused pass per panel pair.
//!
//! The fused elementwise update runs natively or through the AOT PJRT
//! artifact (`nmf_w_k*` — the L1 Pallas kernel) when the full factor is
//! memory-resident and k is a supported artifact shape.

use super::TallPanels;
use crate::io::{CacheUsage, ShardedStore};
use crate::matrix::{ops, DenseMatrix, NumaDense};
use crate::metrics::Stopwatch;
use crate::runtime::DenseBackend;
use crate::spmm::{engine, exec, OutputSink, Source, SpmmOpts, StreamPass};
use anyhow::{bail, Result};
use std::sync::Arc;

const EPS: f32 = 1e-9;

/// NMF configuration.
#[derive(Debug, Clone)]
pub struct NmfConfig {
    /// Factorization rank.
    pub k: usize,
    pub iterations: usize,
    /// Factor columns kept in memory (panel width; must divide k).
    /// `cols_in_mem == k` keeps the factors fully in memory.
    pub cols_in_mem: usize,
    pub spmm: SpmmOpts,
    /// Offload the fused update to a dense backend (the PJRT artifacts
    /// when built with `--features pjrt` + `make artifacts`, or the
    /// native backend) when possible.
    pub backend: Option<Arc<dyn DenseBackend>>,
    /// Fuse `A·Hᵀ`, `Aᵀ·W` and the residual reduction into **one**
    /// streaming sweep of A per iteration (default). `false` issues two
    /// single-op sweeps with identical math — the I/O baseline the
    /// `fused_ops` bench experiment compares against.
    pub fused: bool,
    pub seed: u64,
}

impl Default for NmfConfig {
    fn default() -> Self {
        NmfConfig {
            k: 16,
            iterations: 10,
            cols_in_mem: 16,
            spmm: SpmmOpts::default(),
            backend: None,
            fused: true,
            seed: 0x17F,
        }
    }
}

/// Per-run result.
#[derive(Debug)]
pub struct NmfResult {
    /// ‖A − WH‖_F of the factors *entering* each iteration (computed
    /// in-pass; see the module docs for the residual convention).
    pub residuals: Vec<f64>,
    /// Wall-clock seconds of each iteration.
    pub secs_per_iter: Vec<f64>,
    /// Wall-clock seconds of the whole run.
    pub secs: f64,
    /// Logical bytes read at the array interface.
    pub bytes_read: u64,
    /// Logical bytes written at the array interface.
    pub bytes_written: u64,
    /// Streaming sweeps of the sparse image issued over the whole run
    /// (fused: `iterations × panels`; two-pass: twice that).
    pub sparse_passes: usize,
    /// Logical sparse-image bytes streamed per iteration (the SEM
    /// currency the fusion halves-or-better — one pass per panel pair
    /// instead of the old three over two images).
    pub sparse_bytes_per_iter: Vec<u64>,
    /// Tile-row cache activity of the single A source (with a cache
    /// budget covering the image, iterations after the first read
    /// nothing from the store).
    pub cache: Option<CacheUsage>,
    /// The W factor, as stored panels.
    pub w: TallPanels,
    /// The Hᵀ factor, as stored panels.
    pub ht: TallPanels,
}

/// Run NMF over the single stored image of A (`src_a`); no transpose
/// image is needed — `Aᵀ·W` comes out of the same sweep via the scatter
/// kernels.
pub fn nmf(src_a: &Source, store: &Arc<ShardedStore>, cfg: &NmfConfig) -> Result<NmfResult> {
    let n = src_a.meta().nrows;
    if src_a.meta().ncols != n {
        bail!("nmf needs a square A image");
    }
    let k = cfg.k;
    let w_cols = cfg.cols_in_mem;
    if w_cols == 0 || k % w_cols != 0 {
        bail!("cols_in_mem ({w_cols}) must divide k ({k})");
    }
    let np = k / w_cols;
    let in_mem = np == 1;
    // ‖A‖²_F for the residual. For Mem/Sem images every stored entry of
    // the binary adjacency contributes 1, so `meta().nnz` is exact —
    // but under a delta overlay that is the stale base count, so stream
    // the merged view once instead (also exact for weighted overlays:
    // Σv² is the true Frobenius mass).
    let a_fro2 = match src_a {
        Source::Delta(_) => {
            let mut s = 0f64;
            src_a.for_each_edge(|_, _, v| s += v as f64 * v as f64)?;
            s
        }
        _ => src_a.meta().nnz as f64,
    };
    let ncfg = engine::numa_config(src_a.meta().tile, n, &cfg.spmm);

    let read0 = store.stats.bytes_read.get();
    let written0 = store.stats.bytes_written.get();
    // Resolve the source's cache up front, so the baseline and the final
    // reading come from the same cache across budget changes.
    let cache = src_a.resolve_tile_cache(&cfg.spmm);
    let cache0 = cache.as_ref().map(|c| c.usage()).unwrap_or_default();
    let sw = Stopwatch::start();

    let mut w = TallPanels::create(store, "nmf.W", n, w_cols, np, in_mem)?;
    let mut ht = TallPanels::create(store, "nmf.Ht", n, w_cols, np, in_mem)?;
    // Next-generation targets: the simultaneous update reads every old
    // panel, so new panels land in a second set and the two swap each
    // iteration (keeps SEM placement at O(n·b) resident floats).
    let mut w_next = TallPanels::create(store, "nmf.W.next", n, w_cols, np, in_mem)?;
    let mut ht_next = TallPanels::create(store, "nmf.Ht.next", n, w_cols, np, in_mem)?;
    {
        // Initialize from a full-width random factor sliced into panels so
        // the starting point (and hence the whole trajectory) is identical
        // for every `cols_in_mem` setting.
        let w0 = DenseMatrix::random(n, k, cfg.seed);
        let h0 = DenseMatrix::random(n, k, cfg.seed ^ 0x8000);
        for q in 0..np {
            w.store(q, &w0.col_slice(q * w_cols, (q + 1) * w_cols))?;
            ht.store(q, &h0.col_slice(q * w_cols, (q + 1) * w_cols))?;
        }
    }

    let mut residuals = Vec::with_capacity(cfg.iterations);
    let mut secs_per_iter = Vec::with_capacity(cfg.iterations);
    let mut sparse_bytes_per_iter = Vec::with_capacity(cfg.iterations);
    let mut sparse_passes = 0usize;
    for _it in 0..cfg.iterations {
        let isw = Stopwatch::start();
        let wtw = panels_gram(&w)?;
        let hht = panels_gram(&ht)?;
        let mut inner = 0f64; // ⟨Aᵀ W, Hᵀ⟩, fused into the sweep(s)
        let mut iter_bytes = 0u64;
        for q in 0..np {
            let wq = w.load(q)?;
            let hq = ht.load(q)?;
            let b = w_cols;

            // One sweep of A: Q_q = A·Hᵀ_q (forward), P_q = Aᵀ·W_q
            // (transpose), ⟨P_q, Hᵀ_q⟩ as a reduce-time hook — or two
            // single-op sweeps when `fused` is off (same numbers).
            let x = NumaDense::from_dense(&hq, ncfg);
            let y = NumaDense::from_dense(&wq, ncfg);
            let q_out = NumaDense::zeros(n, b, ncfg);
            let p_out = NumaDense::zeros(n, b, ncfg);
            let hook = |rows_lo: usize, rows: &mut [f32], acc: &mut [f64]| {
                let h = &hq.data[rows_lo * b..rows_lo * b + rows.len()];
                let mut s = 0f64;
                for (a, c) in rows.iter().zip(h) {
                    s += *a as f64 * *c as f64;
                }
                acc[0] += s;
            };
            if cfg.fused {
                let pass = StreamPass::new()
                    .forward(&x, OutputSink::Mem(&q_out))
                    .transpose_with(&y, &p_out, 1, Box::new(hook));
                let r = exec::run_pass(src_a, &pass, &cfg.spmm)?;
                inner += r.accs[1][0];
                iter_bytes += r.stats.bytes_read;
                sparse_passes += 1;
            } else {
                let pass_t =
                    StreamPass::new().transpose_with(&y, &p_out, 1, Box::new(hook));
                let r1 = exec::run_pass(src_a, &pass_t, &cfg.spmm)?;
                inner += r1.accs[0][0];
                let pass_f = StreamPass::new().forward(&x, OutputSink::Mem(&q_out));
                let r2 = exec::run_pass(src_a, &pass_f, &cfg.spmm)?;
                iter_bytes += r1.stats.bytes_read + r2.stats.bytes_read;
                sparse_passes += 2;
            }
            let p_q = p_out.to_dense();
            let q_q = q_out.to_dense();

            // Hᵀ_q ← Hᵀ_q ∘ P_q ⊘ (Σ_r Hᵀ_r · WᵀW[rb.., qb..] + ε)
            let new_h = update_panel(&ht, &hq, &p_q, &wtw, q, cfg)?;
            // W_q ← W_q ∘ Q_q ⊘ (Σ_r W_r · HHᵀ[rb.., qb..] + ε)
            let new_w = update_panel(&w, &wq, &q_q, &hht, q, cfg)?;
            ht_next.store(q, &new_h)?;
            w_next.store(q, &new_w)?;
        }

        // Residual of the iterate the sweep consumed:
        // ‖A − WH‖² = ‖A‖²_F − 2⟨AᵀW, Hᵀ⟩ + ⟨WᵀW, HHᵀ⟩.
        let frob_term: f64 = wtw
            .data
            .iter()
            .zip(&hht.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let sq = (a_fro2 - 2.0 * inner + frob_term).max(0.0);
        residuals.push(sq.sqrt());
        sparse_bytes_per_iter.push(iter_bytes);

        std::mem::swap(&mut w, &mut w_next);
        std::mem::swap(&mut ht, &mut ht_next);
        secs_per_iter.push(isw.secs());
    }

    Ok(NmfResult {
        residuals,
        secs_per_iter,
        secs: sw.secs(),
        bytes_read: store.stats.bytes_read.get() - read0,
        bytes_written: store.stats.bytes_written.get() - written0,
        sparse_passes,
        sparse_bytes_per_iter,
        cache: cache.map(|c| c.usage().since(&cache0)),
        w,
        ht,
    })
}

/// One panel's multiplicative update `tq ∘ num ⊘ (denom + ε)` against the
/// *iteration-entry* panels of `target`: full-memory panels go through
/// the dense backend when supported, the panelized path accumulates the
/// denominator over every stored panel (the Fig 11 locality loss).
fn update_panel(
    target: &TallPanels,
    tq: &DenseMatrix,
    num: &DenseMatrix,
    g: &DenseMatrix,
    q: usize,
    cfg: &NmfConfig,
) -> Result<DenseMatrix> {
    let b = target.panel_cols();
    let np = target.num_panels();
    let k = b * np;
    if np == 1 {
        return Ok(match &cfg.backend {
            Some(be) if be.supports_k(k) => be.nmf_update_w(tq, num, g)?,
            _ => fused_update_native(tq, num, g),
        });
    }
    // D_q = Σ_r target_r · G[rb.., qb..]
    let mut denom = DenseMatrix::zeros(target.nrows(), b);
    for r in 0..np {
        let tr = target.load(r)?;
        let mut gblk = DenseMatrix::zeros(b, b);
        for i in 0..b {
            for j in 0..b {
                gblk.set(i, j, g.get(r * b + i, q * b + j));
            }
        }
        ops::axpy(&mut denom, 1.0, &ops::mul_small(&tr, &gblk));
    }
    let mut out = DenseMatrix::zeros(target.nrows(), b);
    for i in 0..out.data.len() {
        out.data[i] = tq.data[i] * num.data[i] / (denom.data[i] + EPS);
    }
    Ok(out)
}

/// Gram matrix of a panel-stored tall factor (k×k), accumulating panel
/// cross-terms two panels at a time.
fn panels_gram(x: &TallPanels) -> Result<DenseMatrix> {
    let b = x.panel_cols();
    let k = b * x.num_panels();
    let mut g = DenseMatrix::zeros(k, k);
    for q in 0..x.num_panels() {
        let xq = x.load(q)?;
        for r in q..x.num_panels() {
            let blk = if r == q {
                ops::gram(&xq)
            } else {
                let xr = x.load(r)?;
                ops::xty(&xq, &xr)
            };
            for i in 0..b {
                for j in 0..b {
                    g.set(q * b + i, r * b + j, blk.get(i, j));
                    g.set(r * b + j, q * b + i, blk.get(i, j));
                }
            }
        }
    }
    Ok(g)
}

/// Native fused update: `t ∘ num ⊘ (t · G + ε)`.
fn fused_update_native(t: &DenseMatrix, num: &DenseMatrix, g: &DenseMatrix) -> DenseMatrix {
    let denom = ops::mul_small(t, g);
    let mut out = DenseMatrix::zeros(t.nrows, t.ncols);
    for i in 0..out.data.len() {
        out.data[i] = t.data[i] * num.data[i] / (denom.data[i] + EPS);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::rmat;
    use crate::io::StoreSpec;
    use crate::spmm::SemSource;

    fn setup(scale: u32, edges: usize) -> Arc<TiledImage> {
        let el = rmat::generate(scale, edges, rmat::RmatParams::default(), 31);
        let m = Csr::from_edgelist(&el);
        Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr))
    }

    #[test]
    fn residual_decreases() {
        let a = setup(8, 2000);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 8,
            iterations: 6,
            cols_in_mem: 8,
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let res = nmf(&Source::Mem(a), &store, &cfg).unwrap();
        assert_eq!(res.residuals.len(), 6);
        for w in res.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * 1.01,
                "residual must not increase: {} -> {}",
                w[0],
                w[1]
            );
        }
        assert!(
            res.residuals.last().unwrap() < &(res.residuals[0] * 0.95),
            "residual must decrease overall"
        );
    }

    #[test]
    fn panelized_matches_full_memory() {
        let a = setup(7, 900);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let run = |cols: usize| {
            let cfg = NmfConfig {
                k: 4,
                iterations: 4,
                cols_in_mem: cols,
                spmm: SpmmOpts::sequential(),
                ..Default::default()
            };
            nmf(&Source::Mem(a.clone()), &store, &cfg).unwrap().residuals
        };
        let full = run(4);
        let panel2 = run(2);
        let panel1 = run(1);
        for i in 0..full.len() {
            assert!(
                (full[i] - panel2[i]).abs() < 1e-2 * full[i].max(1.0),
                "iter {i}: {} vs {}",
                full[i],
                panel2[i]
            );
            assert!((full[i] - panel1[i]).abs() < 1e-2 * full[i].max(1.0));
        }
    }

    #[test]
    fn panelized_run_touches_store() {
        let a = setup(7, 800);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 4,
            iterations: 2,
            cols_in_mem: 2,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let res = nmf(&Source::Mem(a), &store, &cfg).unwrap();
        assert!(res.bytes_read > 0 && res.bytes_written > 0);
    }

    #[test]
    fn backend_fused_update_matches_native() {
        // The PJRT backend when artifacts are built, the native backend
        // otherwise — either must reproduce the open-coded update.
        let be = crate::runtime::backend_from_env()
            .unwrap_or_else(crate::runtime::default_backend);
        let a = setup(7, 900);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let base = NmfConfig {
            k: 16,
            iterations: 3,
            cols_in_mem: 16,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let plain = nmf(&Source::Mem(a.clone()), &store, &base).unwrap().residuals;
        let be_cfg = NmfConfig {
            backend: Some(be),
            ..base
        };
        let offloaded = nmf(&Source::Mem(a), &store, &be_cfg).unwrap().residuals;
        for (n, x) in plain.iter().zip(&offloaded) {
            assert!(
                (n - x).abs() < 1e-2 * n.max(1.0),
                "plain {n} vs backend {x}"
            );
        }
    }

    #[test]
    fn delta_overlay_residual_matches_full_reconversion() {
        // Under a delta overlay `meta().nnz` is the stale base count;
        // the residual must use the effective Frobenius mass, so the
        // trajectory over a DeltaSource equals a from-scratch
        // reconversion of the mutated matrix exactly.
        use crate::format::delta::DeltaOp;
        use crate::io::{DeltaConfig, DeltaStore};
        let el = rmat::generate(7, 900, rmat::RmatParams::default(), 31);
        let m = Csr::from_edgelist(&el);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        store.put("a.semm", &buf).unwrap();

        // Insert fresh edges and delete existing ones so the effective
        // count moves both ways off the base nnz. Compaction is held
        // off so the sweep really runs base ⊕ overlay with a stale
        // `meta().nnz` — the path under test.
        let dcfg = DeltaConfig {
            compact_runs: usize::MAX,
            major_compact_ratio: f64::INFINITY,
            ..Default::default()
        };
        let ds = DeltaStore::open(&store, "a.semm", dcfg).unwrap();
        let n = img.meta.nrows as u32;
        let mut edits = Vec::new();
        for k in 0..160u32 {
            let (r, c) = ((k * 11) % n, (k * 29) % n);
            let op = if k % 4 == 0 {
                DeltaOp::delete(r, c)
            } else {
                DeltaOp::upsert(r, c, 1.0)
            };
            ds.stage(op).unwrap();
            edits.push(op);
        }
        ds.commit().unwrap();
        assert!(!ds.manifest().unwrap().runs.is_empty(), "edits must stay an overlay");
        let src = Source::Delta(crate::spmm::DeltaSource::open(&store, "a.semm").unwrap());

        // Reference: the mutated edge set converted from scratch.
        let mut set: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();
        for r in 0..m.nrows {
            for k in m.indptr[r] as usize..m.indptr[r + 1] as usize {
                set.insert((r as u32, m.indices[k]));
            }
        }
        for op in &edits {
            if op.tombstone {
                set.remove(&(op.row, op.col));
            } else {
                set.insert((op.row, op.col));
            }
        }
        let pairs: Vec<(u32, u32)> = set.into_iter().collect();
        let m2 = Csr::from_sorted_pairs(m.nrows, m.ncols, &pairs);
        let ref_img = Arc::new(TiledImage::build(&m2, 64, TileFormat::Scsr));
        assert_ne!(ref_img.meta.nnz, img.meta.nnz, "edits must change the count");

        let cfg = NmfConfig {
            k: 4,
            iterations: 3,
            cols_in_mem: 4,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let got = nmf(&src, &store, &cfg).unwrap().residuals;
        let want = nmf(&Source::Mem(ref_img), &store, &cfg).unwrap().residuals;
        assert_eq!(got, want, "delta residuals must match reconversion exactly");
    }

    #[test]
    fn invalid_panel_width_rejected() {
        let a = setup(6, 300);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = NmfConfig {
            k: 16,
            cols_in_mem: 3,
            ..Default::default()
        };
        assert!(nmf(&Source::Mem(a), &store, &cfg).is_err());
    }

    #[test]
    fn rectangular_image_rejected() {
        let mut pairs = vec![(0u32, 1u32), (1, 2)];
        pairs.sort_unstable();
        let m = Csr::from_sorted_pairs(3, 5, &pairs);
        let a = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        assert!(nmf(&Source::Mem(a), &store, &NmfConfig::default()).is_err());
    }

    /// The acceptance property of the fusion: identical trajectories,
    /// half the sparse I/O, one streaming pass per iteration.
    #[test]
    fn fused_matches_two_pass_and_halves_sparse_reads() {
        let img = setup(8, 2500);
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let iters = 4usize;
        let run = |fused: bool| {
            let dir = crate::util::tempdir();
            let store =
                ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
            store.put("a.semm", &buf).unwrap();
            let src = Source::Sem(SemSource::open(&store, "a.semm").unwrap());
            let cfg = NmfConfig {
                k: 8,
                iterations: iters,
                cols_in_mem: 8,
                fused,
                spmm: SpmmOpts {
                    threads: 3,
                    ..Default::default()
                },
                ..Default::default()
            };
            nmf(&src, &store, &cfg).unwrap()
        };
        let fused = run(true);
        let two_pass = run(false);

        // Same math: residual trajectories and final factors agree.
        for (i, (a, b)) in fused
            .residuals
            .iter()
            .zip(&two_pass.residuals)
            .enumerate()
        {
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "iter {i}: fused {a} vs two-pass {b}"
            );
        }
        let wf = fused.w.load(0).unwrap();
        let wt = two_pass.w.load(0).unwrap();
        let scale = wt.data.iter().fold(1f32, |a, &v| a.max(v.abs()));
        assert!(wf.max_abs_diff(&wt) <= 1e-4 * scale, "W factors diverge");
        let hf = fused.ht.load(0).unwrap();
        let htp = two_pass.ht.load(0).unwrap();
        assert!(hf.max_abs_diff(&htp) <= 1e-4 * scale, "Hᵀ factors diverge");

        // Exactly one streaming pass per iteration, half the two-pass
        // logical sparse reads (and far below the old three-stream,
        // two-image numbers).
        assert_eq!(fused.sparse_passes, iters);
        assert_eq!(two_pass.sparse_passes, 2 * iters);
        for (f, t) in fused
            .sparse_bytes_per_iter
            .iter()
            .zip(&two_pass.sparse_bytes_per_iter)
        {
            assert!(*f > 0, "fused iteration must stream the image");
            assert!(
                *f * 2 <= *t + 16,
                "fused reads {f} not half of two-pass {t}"
            );
        }
    }
}
