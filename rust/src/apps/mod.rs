//! The paper's three applications (§4): PageRank, a Krylov–Schur
//! eigensolver, and non-negative matrix factorization. Each demonstrates a
//! different memory-placement strategy for SEM-SpMM:
//!
//! * [`pagerank`] — dense matrices are single vectors; the input vector
//!   must be in memory, the output and degree vectors may live on the
//!   store (Fig 14's SEM-1vec/2vec/3vec).
//! * [`eigen`] — the vector subspace is a tall n×m matrix updated in
//!   blocks of 1–4 columns; it can live entirely on the store (SEM-min)
//!   or entirely in memory (SEM-max) (Fig 15).
//! * [`nmf`] — the factors W, H are as large as the sparse matrix and are
//!   vertically partitioned; the number of factor columns kept in memory
//!   is the Fig 16 knob.
//!
//! [`TallPanels`] is the shared abstraction: a tall dense matrix stored as
//! fixed-width column panels either in memory or on the store, so the
//! apps' streaming algebra is written once against both placements.
//!
//! Three graph-traversal apps run the *same* streaming sweep under
//! non-arithmetic semirings ([`crate::spmm::semiring`]) — the traversal
//! state is a handful of n×1 vectors, so each works on graphs far larger
//! than memory:
//!
//! * [`bfs`] — frontier BFS, one or-and sweep per level.
//! * [`sssp`] — Bellman–Ford SSSP, one min-plus sweep per round, plus a
//!   streaming edge scan that recovers the shortest-path tree.
//! * [`labelprop`] — min-label propagation / connected components, one
//!   min-select sweep per round.

pub mod bfs;
pub mod eigen;
pub mod labelprop;
pub mod nmf;
pub mod pagerank;
pub mod sssp;

use crate::io::ShardedStore;
use crate::matrix::{DenseMatrix, SemDense};
use anyhow::Result;
use std::sync::Arc;

/// A tall n×(panels·b) matrix stored as n×b column panels, either in
/// memory or on the store. Apps stream panels through memory one (or a
/// few) at a time, which is exactly the paper's memory model.
#[derive(Debug, Clone)]
pub enum TallPanels {
    Mem(Vec<DenseMatrix>),
    Sem(SemDense),
}

impl TallPanels {
    /// Create with `num_panels` panels of shape n×b.
    pub fn create(
        store: &Arc<ShardedStore>,
        name: &str,
        n: usize,
        b: usize,
        num_panels: usize,
        in_mem: bool,
    ) -> Result<TallPanels> {
        if in_mem {
            Ok(TallPanels::Mem(
                (0..num_panels).map(|_| DenseMatrix::zeros(n, b)).collect(),
            ))
        } else {
            Ok(TallPanels::Sem(SemDense::create(
                store,
                name,
                n,
                b * num_panels,
                b,
            )?))
        }
    }

    pub fn num_panels(&self) -> usize {
        match self {
            TallPanels::Mem(v) => v.len(),
            TallPanels::Sem(sd) => sd.num_panels(),
        }
    }

    pub fn panel_cols(&self) -> usize {
        match self {
            TallPanels::Mem(v) => v.first().map(|m| m.ncols).unwrap_or(0),
            TallPanels::Sem(sd) => sd.panel_cols,
        }
    }

    pub fn nrows(&self) -> usize {
        match self {
            TallPanels::Mem(v) => v.first().map(|m| m.nrows).unwrap_or(0),
            TallPanels::Sem(sd) => sd.nrows,
        }
    }

    /// Load panel `i` into memory (In-EM traffic in SEM placement).
    pub fn load(&self, i: usize) -> Result<DenseMatrix> {
        match self {
            TallPanels::Mem(v) => Ok(v[i].clone()),
            TallPanels::Sem(sd) => sd.load_panel(i),
        }
    }

    /// Borrow panel `i` without copying — `Some` only for the in-memory
    /// placement. Fused pass hooks use this to read every panel while
    /// SpMM output intervals are finalized (SEM placement falls back to
    /// explicit [`Self::load`] sweeps, since its panels live on the
    /// store).
    pub fn panel_ref(&self, i: usize) -> Option<&DenseMatrix> {
        match self {
            TallPanels::Mem(v) => v.get(i),
            TallPanels::Sem(_) => None,
        }
    }

    /// Store panel `i` (Out-EM traffic in SEM placement).
    pub fn store(&mut self, i: usize, m: &DenseMatrix) -> Result<()> {
        match self {
            TallPanels::Mem(v) => {
                v[i] = m.clone();
                Ok(())
            }
            TallPanels::Sem(sd) => sd.store_panel(i, m),
        }
    }

    /// Logical bytes held in memory by this placement (Fig 8/15 metering).
    pub fn mem_bytes(&self) -> u64 {
        match self {
            TallPanels::Mem(v) => v.iter().map(|m| m.footprint_bytes()).sum(),
            TallPanels::Sem(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::StoreSpec;

    #[test]
    fn mem_and_sem_placements_agree() {
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        for in_mem in [true, false] {
            let mut tp =
                TallPanels::create(&store, "v", 50, 2, 3, in_mem).unwrap();
            assert_eq!(tp.num_panels(), 3);
            assert_eq!(tp.panel_cols(), 2);
            let p = DenseMatrix::random(50, 2, 7);
            tp.store(1, &p).unwrap();
            assert_eq!(tp.load(1).unwrap(), p);
            // Untouched panels are zero.
            assert!(tp.load(0).unwrap().data.iter().all(|&v| v == 0.0));
            assert_eq!(tp.mem_bytes() > 0, in_mem);
        }
    }
}
