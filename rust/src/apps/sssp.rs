//! Single-source shortest paths as min-plus (tropical) semiring sweeps.
//!
//! Under [`MinPlus`], one streaming pass `y = A ⊗ x` relaxes every edge
//! once: `y[v] = minᵤ (A[v][u] + x[u])` over `v`'s in-neighbors, where
//! `A[v][u]` is the weight of edge `u → v` (binary images degrade to
//! hop counts — every edge weighs [`crate::spmm::Semiring::PATTERN`] =
//! 1). Iterating to a fixpoint is Bellman–Ford, in its Jacobi form: each
//! round reads the previous round's distances only. A fused [`RowHook`]
//! folds the old distance in (`d' = min(y, d)`), counts changed vertices
//! for convergence detection, records the new distances, and leaves them
//! in the pass output — which is the next round's input directly, so one
//! SSSP round is one matrix sweep and zero extra vector sweeps.
//!
//! **Parent tracking.** At the fixpoint, every reached non-root vertex
//! `v` has at least one in-edge `(u, v, w)` with `dist[u] + w ==
//! dist[v]` *exactly* (its distance was computed as that very f32 sum),
//! so parents need no bookkeeping during the sweeps: one final
//! streaming edge scan ([`Source::for_each_edge`]) recovers a shortest
//! -path tree, picking the smallest qualifying `u` per vertex for
//! determinism.
//!
//! Weights must be non-negative (Bellman–Ford's convergence bound; the
//! engine never checks, it just won't converge on negative cycles).

use crate::metrics::Stopwatch;
use crate::matrix::NumaDense;
use crate::spmm::{engine, exec, MinPlus, OutputSink, RowHook, Source, SpmmOpts, StreamPass};
use anyhow::{bail, Result};

/// SSSP configuration.
#[derive(Debug, Clone)]
pub struct SsspConfig {
    /// Relaxation-round cap; the default runs to the fixpoint (at most
    /// `n − 1` rounds on non-negative weights).
    pub max_iters: usize,
    /// Skip the final edge scan and return an empty parent vector.
    pub skip_parents: bool,
    /// Engine options for each sweep.
    pub spmm: SpmmOpts,
}

impl Default for SsspConfig {
    fn default() -> Self {
        SsspConfig {
            max_iters: usize::MAX,
            skip_parents: false,
            spmm: SpmmOpts::default(),
        }
    }
}

/// Run statistics.
#[derive(Debug, Clone, Default)]
pub struct SsspStats {
    /// Wall-clock seconds of the whole run (including the parent scan).
    pub secs: f64,
    /// Relaxation rounds executed.
    pub iters: usize,
    /// Whether a round with zero improvements was reached.
    pub converged: bool,
    /// Vertices with a finite distance, including the root.
    pub reached: u64,
    /// Vertices whose distance improved, per round.
    pub relaxed: Vec<u64>,
    /// Logical sparse-matrix bytes read across all sweeps and the parent
    /// scan (SEM mode; 0 for IM).
    pub bytes_read: u64,
}

/// Shortest paths from `root` over a weighted (or binary) adjacency
/// image (`row = dst`, `col = src`). Returns per-vertex distances
/// (`+∞` = unreached), a shortest-path tree (`parent[v] = -1` for the
/// root and unreached vertices), and run statistics.
pub fn sssp(src: &Source, root: u32, cfg: &SsspConfig) -> Result<(Vec<f32>, Vec<i64>, SsspStats)> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n {
        bail!("sssp needs a square adjacency image");
    }
    if root as usize >= n {
        bail!("sssp root {root} out of range (n = {n})");
    }
    let sw = Stopwatch::start();
    let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
    let mut x = NumaDense::zeros(n, 1, ncfg);
    let mut x_next = NumaDense::zeros(n, 1, ncfg);
    let mut dist = NumaDense::zeros(n, 1, ncfg);
    x.fill(f32::INFINITY);
    dist.fill(f32::INFINITY);
    x.row_mut(root as usize)[0] = 0.0;
    dist.row_mut(root as usize)[0] = 0.0;

    let mut iters = 0usize;
    let mut converged = false;
    let mut relaxed = Vec::new();
    let mut bytes_read = 0u64;
    while iters < cfg.max_iters {
        let dref = &dist;
        // Fold the previous distances into the relaxation result while
        // the rows are hot: d' = min(y, d), count improvements, persist
        // d', and leave d' in the outgoing rows (the next round's input).
        let hook: RowHook = Box::new(move |lo: usize, rows: &mut [f32], acc: &mut [f64]| {
            let hi = lo + rows.len();
            let mut dbuf: Vec<f32> = (lo..hi).map(|g| dref.row(g)[0]).collect();
            for (i, r) in rows.iter_mut().enumerate() {
                if *r < dbuf[i] {
                    dbuf[i] = *r;
                    acc[0] += 1.0;
                } else {
                    *r = dbuf[i];
                }
            }
            unsafe { dref.write_rows_unsync(lo, hi, &dbuf) };
        });
        let r = {
            let pass =
                StreamPass::<MinPlus>::new().forward_with(&x, OutputSink::Mem(&x_next), 1, hook);
            exec::run_pass_ring(src, &pass, &cfg.spmm)?
        };
        bytes_read += r.stats.bytes_read;
        let improved = r.accs[0][0] as u64;
        iters += 1;
        if improved == 0 {
            converged = true;
            break;
        }
        relaxed.push(improved);
        std::mem::swap(&mut x, &mut x_next);
    }

    let dists: Vec<f32> = (0..n).map(|i| dist.row(i)[0]).collect();
    let reached = dists.iter().filter(|d| d.is_finite()).count() as u64;

    // One streaming edge scan recovers a shortest-path tree (see the
    // module docs for why exact f32 equality is the right test here).
    let parents: Vec<i64> = if cfg.skip_parents {
        Vec::new()
    } else {
        let scan_read0 = match src {
            Source::Sem(s) => s.file.store().stats.bytes_read.get(),
            Source::Delta(d) => d.base.file.store().stats.bytes_read.get(),
            Source::Mem(_) => 0,
        };
        let mut parent = vec![-1i64; n];
        src.for_each_edge(|r, c, w| {
            let (v, u) = (r as usize, c as usize);
            let du = dists[u];
            if du.is_finite() && du + w == dists[v] {
                let cand = u as i64;
                if parent[v] < 0 || cand < parent[v] {
                    parent[v] = cand;
                }
            }
        })?;
        parent[root as usize] = -1;
        match src {
            Source::Sem(s) => {
                bytes_read += s.file.store().stats.bytes_read.get() - scan_read0;
            }
            Source::Delta(d) => {
                bytes_read += d.base.file.store().stats.bytes_read.get() - scan_read0;
            }
            Source::Mem(_) => {}
        }
        parent
    };

    Ok((
        dists,
        parents,
        SsspStats {
            secs: sw.secs(),
            iters,
            converged,
            reached,
            relaxed,
            bytes_read,
        },
    ))
}

/// Jacobi Bellman–Ford reference over a weighted edge list (test
/// oracle). An edge tuple `(r, c, w)` is the matrix entry `A[r][c] = w`,
/// i.e. the directed edge `c → r` with weight `w`. Computed in f32 with
/// the same per-round simultaneous update the engine performs, so the
/// results match the streamed run **exactly**.
pub fn sssp_ref(num_verts: usize, edges: &[(u32, u32, f32)], root: u32) -> Vec<f32> {
    let mut d = vec![f32::INFINITY; num_verts];
    d[root as usize] = 0.0;
    loop {
        let mut nd = d.clone();
        let mut changed = false;
        for &(r, c, w) in edges {
            let du = d[c as usize];
            if du.is_finite() {
                let cand = du + w;
                if cand < nd[r as usize] {
                    nd[r as usize] = cand;
                    changed = true;
                }
            }
        }
        d = nd;
        if !changed {
            break;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::bfs::{bfs, bfs_ref, BfsConfig};
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::rmat;
    use crate::io::{ShardedStore, StoreSpec};
    use crate::spmm::SemSource;
    use std::collections::HashMap;
    use std::sync::Arc;

    /// Deterministic positive weight for edge `A[r][c]` — both the image
    /// and the reference derive weights from this one function.
    fn weight(r: u32, c: u32) -> f32 {
        ((r.wrapping_mul(31) ^ c.wrapping_mul(17)) % 13 + 1) as f32 / 4.0
    }

    /// Weighted image + weighted edge list from an RMAT graph.
    fn weighted(scale: u32, edges: usize, seed: u64, tile: usize, fmt: TileFormat)
        -> (Vec<(u32, u32, f32)>, Arc<TiledImage>, usize) {
        let mut el = rmat::generate(scale, edges, rmat::RmatParams::default(), seed);
        el.dedup();
        let mut m = Csr::from_edgelist(&el);
        let mut vals = Vec::with_capacity(m.nnz());
        for r in 0..m.nrows {
            for &c in m.row(r) {
                vals.push(weight(r as u32, c));
            }
        }
        m.vals = Some(vals);
        let wedges: Vec<(u32, u32, f32)> = el
            .edges
            .iter()
            .map(|&(r, c)| (r, c, weight(r, c)))
            .collect();
        let n = el.num_verts;
        (wedges, Arc::new(TiledImage::build(&m, tile, fmt)), n)
    }

    /// Every reached non-root vertex must have a valid tree edge, and
    /// parent chains must terminate at the root.
    fn check_tree(dists: &[f32], parents: &[i64], wedges: &[(u32, u32, f32)], root: u32) {
        let w: HashMap<(u32, u32), f32> =
            wedges.iter().map(|&(r, c, v)| ((r, c), v)).collect();
        for v in 0..dists.len() {
            if v == root as usize || !dists[v].is_finite() {
                assert_eq!(parents[v], -1, "vertex {v}");
                continue;
            }
            let p = parents[v];
            assert!(p >= 0, "reached vertex {v} needs a parent");
            let wvp = w[&(v as u32, p as u32)];
            assert_eq!(dists[p as usize] + wvp, dists[v], "tree edge {p}→{v}");
            // Walk to the root; distances strictly decrease along the
            // chain (positive weights), so it must terminate.
            let (mut cur, mut hops) = (v, 0usize);
            while cur != root as usize {
                cur = parents[cur] as usize;
                hops += 1;
                assert!(hops <= dists.len(), "parent cycle at {v}");
            }
        }
    }

    #[test]
    fn weighted_distances_match_bellman_ford_exactly() {
        for fmt in [TileFormat::Scsr, TileFormat::Dcsc] {
            let (wedges, img, n) = weighted(9, 4000, 41, 128, fmt);
            let want = sssp_ref(n, &wedges, 0);
            let cfg = SsspConfig {
                spmm: SpmmOpts {
                    threads: 3,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (d, p, stats) = sssp(&Source::Mem(img), 0, &cfg).unwrap();
            assert!(stats.converged);
            assert_eq!(d, want, "{fmt:?}: f32 trajectories must be identical");
            assert_eq!(
                stats.reached,
                want.iter().filter(|x| x.is_finite()).count() as u64
            );
            check_tree(&d, &p, &wedges, 0);
        }
    }

    #[test]
    fn sem_run_is_identical_and_streams_matrix_and_parent_scan() {
        let (wedges, img, n) = weighted(8, 2500, 17, 64, TileFormat::Scsr);
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        store.put("sssp.semm", &buf).unwrap();
        let sem = Source::Sem(SemSource::open(&store, "sssp.semm").unwrap());
        let cfg = SsspConfig {
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (d_mem, p_mem, _) = sssp(&Source::Mem(img), 5, &cfg).unwrap();
        let (d_sem, p_sem, stats) = sssp(&sem, 5, &cfg).unwrap();
        assert_eq!(d_mem, d_sem, "SEM must match IM bit for bit");
        assert_eq!(p_mem, p_sem, "deterministic parents either way");
        assert_eq!(d_sem, sssp_ref(n, &wedges, 5));
        assert!(stats.bytes_read > 0, "SEM SSSP must stream the matrix");
        check_tree(&d_sem, &p_sem, &wedges, 5);
    }

    #[test]
    fn binary_graph_distances_are_bfs_hop_counts() {
        let el = rmat::generate(8, 2000, rmat::RmatParams::default(), 23);
        let m = Csr::from_edgelist(&el);
        let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
        let hops = bfs_ref(el.num_verts, &el.edges, 0);
        let cfg = SsspConfig {
            skip_parents: true,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let (d, p, _) = sssp(&Source::Mem(img.clone()), 0, &cfg).unwrap();
        assert!(p.is_empty(), "skip_parents elides the edge scan");
        for (v, (&dv, &hv)) in d.iter().zip(&hops).enumerate() {
            if hv < 0 {
                assert!(dv.is_infinite(), "vertex {v}");
            } else {
                assert_eq!(dv, hv as f32, "vertex {v}");
            }
        }
        // Sanity: the BFS app agrees with itself through the other ring.
        let (lv, _) = bfs(
            &Source::Mem(img),
            0,
            &BfsConfig {
                spmm: SpmmOpts::sequential(),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(lv, hops);
    }

    #[test]
    fn iteration_cap_reports_nonconvergence() {
        let (wedges, img, n) = weighted(8, 2000, 29, 128, TileFormat::Scsr);
        let full = sssp_ref(n, &wedges, 0);
        let cfg = SsspConfig {
            max_iters: 1,
            skip_parents: true,
            spmm: SpmmOpts::sequential(),
            ..Default::default()
        };
        let (d, _, stats) = sssp(&Source::Mem(img), 0, &cfg).unwrap();
        assert_eq!(stats.iters, 1);
        assert!(!stats.converged);
        // One round = direct edges from the root only; never better than
        // the fixpoint.
        for (v, (&dv, &fv)) in d.iter().zip(&full).enumerate() {
            assert!(dv >= fv, "vertex {v}: capped {dv} < fixpoint {fv}");
        }
    }
}
