//! SpMM-based PageRank (§4.1, Fig 14) with a fully fused iteration.
//!
//! `pr' = (1−d)/N + d · A (pr ⊘ L)` where `A[dst][src] = 1` for an edge
//! `src → dst` and `L` is the out-degree vector.
//!
//! In the default configuration (`vecs_in_mem = 3`, native combine) the
//! whole iteration is **one streaming pass with zero post-SpMM sweeps
//! over the dense vectors**: a fused [`crate::spmm::StreamPass`] hook
//! runs on every finished output row interval while those rows are hot —
//! it applies the damping combine, accumulates the L1 residual
//! `Σ|pr'ᵥ − prᵥ|` and the total probability mass in-pass, records the
//! new `pr` values, and writes the *already degree-normalized* next
//! input `pr' ⊘ L` to the output vector, which becomes the next pass's
//! input directly. The residual drives optional early termination
//! ([`PageRankConfig::tol`]).
//!
//! The Fig 14 memory knob (`vecs_in_mem`):
//! * **3** — input, output and degree vectors in memory (fused path).
//! * **2** — degree vector streamed from the store every iteration.
//! * **1** — only the input vector in memory: the output is streamed to
//!   the store and read back as the next iteration's input, and the
//!   degree vector is streamed too.
//!
//! All three modes compute identical values; they differ only in I/O
//! traffic — which is what the figure shows. Modes 1–2 (and the
//! offloaded-combine path) keep their explicit combine sweep, since
//! their vectors live on the store; they are the I/O ablation, not the
//! fast path.

use crate::io::{CacheUsage, MergedWriter, ShardedStore};
use crate::matrix::NumaDense;
use crate::metrics::Stopwatch;
use crate::runtime::DenseBackend;
use crate::spmm::{engine, exec, OutputSink, RowHook, Source, SpmmOpts, StreamPass};
use anyhow::{bail, Result};
use std::sync::Arc;

/// PageRank configuration.
#[derive(Debug, Clone)]
pub struct PageRankConfig {
    /// Maximum iterations (fewer when `tol` converges first).
    pub iterations: usize,
    pub damping: f32,
    /// 1, 2 or 3 — vectors kept in memory (see module docs).
    pub vecs_in_mem: usize,
    /// L1 convergence tolerance on `Σ|pr'ᵥ − prᵥ|`; `0` (the default)
    /// always runs the full `iterations`. The residual is computed
    /// in-pass, so convergence checking costs no extra vector sweep.
    pub tol: f64,
    pub spmm: SpmmOpts,
    /// Offload the combine step to a dense backend (the AOT PJRT
    /// artifact when available, or the native backend).
    pub combine_backend: Option<Arc<dyn DenseBackend>>,
    /// Start from a previous PageRank vector instead of the uniform
    /// `1/N` — the incremental-refresh hook after delta-layer edge
    /// updates: the fixpoint is unique, so a warm start changes only
    /// how many iterations convergence takes, never the answer.
    pub warm_start: Option<Vec<f32>>,
}

impl Default for PageRankConfig {
    fn default() -> Self {
        PageRankConfig {
            iterations: 30,
            damping: 0.85,
            vecs_in_mem: 3,
            tol: 0.0,
            spmm: SpmmOpts::default(),
            combine_backend: None,
            warm_start: None,
        }
    }
}

/// Run statistics.
#[derive(Debug, Clone, Default)]
pub struct PageRankStats {
    /// Wall-clock seconds of the whole run.
    pub secs: f64,
    /// Iterations executed (≤ the configured maximum under `tol`).
    pub iters: usize,
    /// Logical bytes read at the array interface during the run.
    pub bytes_read: u64,
    /// Logical bytes written at the array interface during the run.
    pub bytes_written: u64,
    /// Logical memory held for vectors (the Fig 14 memory story).
    pub vec_mem_bytes: u64,
    /// **Physical** store read requests per iteration (summed over
    /// shards — the device level of the two-level stats). With a
    /// tile-row cache at least the matrix size and `vecs_in_mem = 3`,
    /// every entry after the first is zero.
    pub phys_read_reqs_per_iter: Vec<u64>,
    /// L1 residual `Σ|pr'ᵥ − prᵥ|` per iteration, computed in-pass.
    pub residuals: Vec<f64>,
    /// Total probability mass `Σ pr'ᵥ` per iteration, computed in-pass
    /// (drifts below 1 exactly by the dangling-vertex leak).
    pub mass: Vec<f64>,
    /// Whether `tol` terminated the run before `iterations`.
    pub converged: bool,
    /// Tile-row cache activity during this run (when the SpMM options
    /// carried a cache budget and the source is SEM).
    pub cache: Option<CacheUsage>,
}

/// Degree-vector store object name used by the SEM modes.
const DEG_OBJ: &str = "pagerank.deg";
const OUT_OBJ: &str = "pagerank.out";

/// Run PageRank over an adjacency image (`row = dst`, `col = src`).
/// `out_degrees[v]` is the out-degree of `v`.
pub fn pagerank(
    src: &Source,
    out_degrees: &[u32],
    store: &Arc<ShardedStore>,
    cfg: &PageRankConfig,
) -> Result<(Vec<f32>, PageRankStats)> {
    let meta = src.meta().clone();
    let n = meta.nrows;
    if meta.ncols != n || out_degrees.len() != n {
        bail!("pagerank needs a square adjacency matrix and n degrees");
    }
    if !(1..=3).contains(&cfg.vecs_in_mem) {
        bail!("vecs_in_mem must be 1..=3");
    }
    if let Some(w) = &cfg.warm_start {
        if w.len() != n {
            bail!("warm_start has {} entries for {} vertices", w.len(), n);
        }
    }
    let read0 = store.stats.bytes_read.get();
    let written0 = store.stats.bytes_written.get();
    let sw = Stopwatch::start();

    // Inverse degrees; dangling vertices contribute nothing.
    let inv_deg: Vec<f32> = out_degrees
        .iter()
        .map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 })
        .collect();
    // SEM modes keep the degree vector on the store.
    if cfg.vecs_in_mem < 3 {
        let mut bytes = Vec::with_capacity(n * 4);
        for &v in &inv_deg {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        store.put(DEG_OBJ, &bytes)?;
    }

    // Cache accounting baselines: resolve the cache this run will use
    // up front (as the SEM driver would) so the snapshot and the final
    // reading come from the same cache even across budget changes.
    // Physical reads are metered on the store the matrix lives on (the
    // param store also carries the streamed vectors; they coincide in
    // every harness).
    let cache = src.resolve_tile_cache(&cfg.spmm);
    let cache_usage0 = cache.as_ref().map(|c| c.usage()).unwrap_or_default();
    let phys_store: &Arc<ShardedStore> = match src {
        Source::Sem(s) => s.file.store(),
        Source::Delta(d) => d.base.file.store(),
        Source::Mem(_) => store,
    };
    let mut phys_reads_mark = phys_store.physical_read_reqs();
    let mut phys_reads_per_iter = Vec::with_capacity(cfg.iterations);
    let mut residuals = Vec::with_capacity(cfg.iterations);
    let mut mass_per_iter = Vec::with_capacity(cfg.iterations);
    let vec_mem;

    let fused = cfg.vecs_in_mem == 3 && cfg.combine_backend.is_none();
    let ncfg = engine::numa_config(meta.tile, n, &cfg.spmm);
    let pr0 = 1.0 / n as f32;
    let d = cfg.damping;
    let base = (1.0 - d) / n as f32;
    let mut iters = 0usize;
    let mut converged = false;

    let pr_final: Vec<f32> = if fused {
        // --- Fused path: one pass per iteration, zero vector sweeps.
        let mut x = NumaDense::zeros(n, 1, ncfg);
        let mut x_next = NumaDense::zeros(n, 1, ncfg);
        let mut pr = NumaDense::zeros(n, 1, ncfg);
        match &cfg.warm_start {
            Some(w) => {
                for i in 0..n {
                    pr.row_mut(i)[0] = w[i];
                    x.row_mut(i)[0] = w[i] * inv_deg[i];
                }
            }
            None => {
                pr.fill(pr0);
                for i in 0..n {
                    x.row_mut(i)[0] = pr0 * inv_deg[i];
                }
            }
        }
        vec_mem = x.footprint_bytes() + x_next.footprint_bytes() + pr.footprint_bytes()
            + (n as u64) * 4;
        while iters < cfg.iterations {
            // The hook sees each finished interval of contrib = A·x̂
            // exactly once: combine, meter, record pr', and leave the
            // normalized next input in the outgoing rows.
            let pr_ref = &pr;
            let inv = &inv_deg;
            let hook: RowHook = Box::new(move |rows_lo: usize, rows: &mut [f32], acc: &mut [f64]| {
                for (i, v) in rows.iter_mut().enumerate() {
                    let g = rows_lo + i;
                    let pn = base + d * *v;
                    let old = pr_ref.row(g)[0];
                    acc[0] += (pn as f64 - old as f64).abs();
                    acc[1] += pn as f64;
                    *v = pn;
                }
                // Intervals are finalized exactly once and disjointly.
                unsafe { pr_ref.write_rows_unsync(rows_lo, rows_lo + rows.len(), rows) };
                for (i, v) in rows.iter_mut().enumerate() {
                    *v *= inv[rows_lo + i];
                }
            });
            // Scoped so the pass (and its loans of x / x_next / pr) is
            // dropped before the buffers are swapped below.
            let r = {
                let pass =
                    StreamPass::new().forward_with(&x, OutputSink::Mem(&x_next), 2, hook);
                exec::run_pass(src, &pass, &cfg.spmm)?
            };
            let residual = r.accs[0][0];
            let now = phys_store.physical_read_reqs();
            phys_reads_per_iter.push(now - phys_reads_mark);
            phys_reads_mark = now;
            residuals.push(residual);
            mass_per_iter.push(r.accs[0][1]);
            std::mem::swap(&mut x, &mut x_next);
            iters += 1;
            if cfg.tol > 0.0 && residual < cfg.tol {
                converged = true;
                break;
            }
        }
        (0..n).map(|i| pr.row(i)[0]).collect()
    } else {
        // --- Legacy sweeps: the Fig 14 I/O-ablation modes (vectors on
        // the store) and the offloaded-combine path.
        let mut x = NumaDense::zeros(n, 1, ncfg);
        let mut prev = match &cfg.warm_start {
            Some(w) => w.clone(),
            None => vec![pr0; n],
        };
        for i in 0..n {
            x.row_mut(i)[0] = prev[i];
        }
        vec_mem = x.footprint_bytes()
            + match cfg.vecs_in_mem {
                3 => 2 * (n as u64) * 4, // output + degree in memory
                2 => (n as u64) * 4,     // output in memory
                _ => 0,
            };
        const BLK: usize = 1 << 16;
        let mut deg_blk = vec![0u8; BLK * 4];
        while iters < cfg.iterations {
            // Normalize the input vector by out-degree, streaming the
            // degree vector from the store when it is not memory-resident.
            if cfg.vecs_in_mem < 3 {
                let degf = store.open_file(DEG_OBJ)?;
                let mut r = 0;
                while r < n {
                    let hi = (r + BLK).min(n);
                    let nb = (hi - r) * 4;
                    degf.read_at((r * 4) as u64, &mut deg_blk[..nb])?;
                    for i in r..hi {
                        let dg = f32::from_le_bytes(
                            deg_blk[(i - r) * 4..(i - r) * 4 + 4].try_into().unwrap(),
                        );
                        x.row_mut(i)[0] *= dg;
                    }
                    r = hi;
                }
            } else {
                for i in 0..n {
                    x.row_mut(i)[0] *= inv_deg[i];
                }
            }

            // contrib = A · x̂
            let contrib: Vec<f32> = if cfg.vecs_in_mem == 1 {
                // Output streamed to the store, then read back.
                let outf = store.create_file(OUT_OBJ)?;
                let w = MergedWriter::new(outf, 4 << 20);
                crate::spmm::spmm(src, &x, &cfg.spmm, &OutputSink::Sem(&w))?;
                w.finish()?;
                let bytes = store.get(OUT_OBJ)?;
                bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect()
            } else {
                let out = NumaDense::zeros(n, 1, ncfg);
                crate::spmm::spmm(src, &x, &cfg.spmm, &OutputSink::Mem(&out))?;
                out.to_dense().data
            };

            // pr' = (1 - d)/N + d · contrib — natively or via the backend.
            let pr: Vec<f32> = match &cfg.combine_backend {
                Some(be) => be.pagerank_combine(&contrib, cfg.damping, n)?,
                None => contrib.iter().map(|&c| base + d * c).collect(),
            };
            // Residual/mass ride the combine sweep that already exists in
            // these modes — no additional pass over the vectors.
            let mut residual = 0f64;
            let mut mass = 0f64;
            for (i, &v) in pr.iter().enumerate() {
                residual += (v as f64 - prev[i] as f64).abs();
                mass += v as f64;
                prev[i] = v;
                x.row_mut(i)[0] = v;
            }
            let now = phys_store.physical_read_reqs();
            phys_reads_per_iter.push(now - phys_reads_mark);
            phys_reads_mark = now;
            residuals.push(residual);
            mass_per_iter.push(mass);
            iters += 1;
            if cfg.tol > 0.0 && residual < cfg.tol {
                converged = true;
                break;
            }
        }
        prev
    };

    Ok((
        pr_final,
        PageRankStats {
            secs: sw.secs(),
            iters,
            bytes_read: store.stats.bytes_read.get() - read0,
            bytes_written: store.stats.bytes_written.get() - written0,
            vec_mem_bytes: vec_mem,
            phys_read_reqs_per_iter: phys_reads_per_iter,
            residuals,
            mass: mass_per_iter,
            converged,
            cache: cache.map(|c| c.usage().since(&cache_usage0)),
        },
    ))
}

/// Dense reference PageRank over an edge list (test oracle).
pub fn pagerank_ref(
    num_verts: usize,
    edges: &[(u32, u32)],
    iterations: usize,
    damping: f32,
) -> Vec<f32> {
    let n = num_verts;
    let mut deg = vec![0u32; n];
    for &(_, s) in edges {
        deg[s as usize] += 1;
    }
    let mut pr = vec![1.0 / n as f32; n];
    for _ in 0..iterations {
        let mut contrib = vec![0f32; n];
        for &(d, s) in edges {
            let l = deg[s as usize];
            if l > 0 {
                contrib[d as usize] += pr[s as usize] / l as f32;
            }
        }
        for i in 0..n {
            pr[i] = (1.0 - damping) / n as f32 + damping * contrib[i];
        }
    }
    pr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::{Csr, TileFormat};
    use crate::graph::rmat;
    use crate::io::StoreSpec;

    fn setup(scale: u32, edges: usize) -> (crate::graph::EdgeList, Arc<TiledImage>, Vec<u32>) {
        let el = rmat::generate(scale, edges, rmat::RmatParams::default(), 21);
        let m = Csr::from_edgelist(&el);
        let img = Arc::new(TiledImage::build(&m, 256, TileFormat::Scsr));
        let deg = el.col_degrees();
        (el, img, deg)
    }

    #[test]
    fn matches_reference_all_memory_modes() {
        let (el, img, deg) = setup(9, 4000);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let want = pagerank_ref(el.num_verts, &el.edges, 10, 0.85);
        for vecs in [1, 2, 3] {
            let cfg = PageRankConfig {
                iterations: 10,
                vecs_in_mem: vecs,
                spmm: SpmmOpts {
                    threads: 3,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (pr, stats) = pagerank(&Source::Mem(img.clone()), &deg, &store, &cfg).unwrap();
            assert_eq!(stats.iters, 10);
            for (i, (a, b)) in pr.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5,
                    "mode {vecs}, vertex {i}: {a} vs {b}"
                );
            }
            if vecs == 1 {
                assert!(stats.bytes_written > 0, "mode 1 must stream output");
            }
            // Residual and mass are recorded in every mode.
            assert_eq!(stats.residuals.len(), 10);
            assert_eq!(stats.mass.len(), 10);
        }
    }

    #[test]
    fn probability_mass_conserved_without_dangling() {
        // Symmetrized graph plus a ring so every vertex has an out-edge
        // (isolated vertices would otherwise leak probability mass, as in
        // any PageRank without dangling-node redistribution).
        let mut el = rmat::generate(9, 6000, rmat::RmatParams::default(), 5);
        let n = el.num_verts as u32;
        for v in 0..n {
            el.edges.push((v, (v + 1) % n));
        }
        el.symmetrize();
        let m = Csr::from_edgelist(&el);
        let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
        let deg = el.col_degrees();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = PageRankConfig {
            iterations: 20,
            ..Default::default()
        };
        let (pr, stats) = pagerank(&Source::Mem(img), &deg, &store, &cfg).unwrap();
        let sum: f64 = pr.iter().map(|&v| v as f64).sum();
        assert!((sum - 1.0).abs() < 1e-3, "mass {sum}");
        // The in-pass mass meter must agree with the post-hoc sum.
        let last_mass = *stats.mass.last().unwrap();
        assert!((last_mass - sum).abs() < 1e-6, "{last_mass} vs {sum}");
    }

    #[test]
    fn in_pass_residual_converges_and_stops_early() {
        let (el, img, deg) = setup(9, 5000);
        let _ = el;
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let cfg = PageRankConfig {
            iterations: 200,
            tol: 1e-7,
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (pr, stats) = pagerank(&Source::Mem(img.clone()), &deg, &store, &cfg).unwrap();
        assert!(stats.converged, "must converge before 200 iterations");
        assert!(stats.iters < 200);
        assert!(*stats.residuals.last().unwrap() < 1e-7);
        // Residuals shrink (geometrically, up to float noise).
        assert!(stats.residuals[0] > *stats.residuals.last().unwrap());
        // The converged vector matches a long fixed-iteration reference.
        let ref_cfg = PageRankConfig {
            iterations: stats.iters,
            ..Default::default()
        };
        let (pr_ref, _) = pagerank(&Source::Mem(img), &deg, &store, &ref_cfg).unwrap();
        for (a, b) in pr.iter().zip(&pr_ref) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn full_cache_makes_later_iterations_read_free_and_bit_identical() {
        // The acceptance property of the tile-row cache: with a budget at
        // least the matrix size, the second and later SpMM iterations of
        // a PageRank run perform ZERO physical store reads, and the
        // output is bit-identical to an uncached (budget-0) run.
        let (el, img, deg) = setup(9, 5000);
        let _ = el;
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        let run = |budget: u64| {
            let dir = crate::util::tempdir();
            let store =
                ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
            store.put("pr.semm", &buf).unwrap();
            let src = Source::Sem(
                crate::spmm::SemSource::open(&store, "pr.semm").unwrap(),
            );
            let cfg = PageRankConfig {
                iterations: 6,
                vecs_in_mem: 3,
                spmm: SpmmOpts {
                    threads: 3,
                    cache_budget_bytes: budget,
                    ..Default::default()
                },
                ..Default::default()
            };
            pagerank(&src, &deg, &store, &cfg).unwrap()
        };
        let (pr_cold, cold) = run(0);
        let (pr_warm, warm) = run(1 << 30); // far above the matrix size
        assert_eq!(pr_cold, pr_warm, "cached run must be bit-identical");
        assert!(cold.cache.is_none(), "budget 0 must not attach a cache");
        // Uncached: every iteration hits the store.
        assert!(cold.phys_read_reqs_per_iter.iter().all(|&r| r > 0));
        // Cached: only the first iteration does.
        assert!(warm.phys_read_reqs_per_iter[0] > 0);
        for (i, &r) in warm.phys_read_reqs_per_iter[1..].iter().enumerate() {
            assert_eq!(r, 0, "iteration {} did physical reads", i + 1);
        }
        let usage = warm.cache.expect("cache attached");
        assert!(usage.hits > 0 && usage.bytes_from_cache > 0);
        assert_eq!(usage.bypasses, 0, "full budget admits everything");
    }

    #[test]
    fn warm_start_converges_faster_to_the_same_fixpoint() {
        // The incremental-refresh hook: restarting from a previous
        // PageRank vector must reach the same fixpoint (it is unique)
        // in fewer iterations than a cold uniform start.
        let (el, img, deg) = setup(9, 5000);
        let _ = el;
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let base = PageRankConfig {
            iterations: 200,
            tol: 1e-8,
            spmm: SpmmOpts {
                threads: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (pr_cold, cold) =
            pagerank(&Source::Mem(img.clone()), &deg, &store, &base).unwrap();
        assert!(cold.converged);
        // Warm restart from the converged vector: both paths.
        for vecs in [3, 2] {
            let cfg = PageRankConfig {
                vecs_in_mem: vecs,
                warm_start: Some(pr_cold.clone()),
                ..base.clone()
            };
            let (pr_warm, warm) =
                pagerank(&Source::Mem(img.clone()), &deg, &store, &cfg).unwrap();
            assert!(warm.converged, "mode {vecs}");
            assert!(
                warm.iters < cold.iters,
                "mode {vecs}: warm {} vs cold {}",
                warm.iters,
                cold.iters
            );
            for (a, b) in pr_warm.iter().zip(&pr_cold) {
                assert!((a - b).abs() < 1e-6, "mode {vecs}");
            }
        }
        // A wrong-length warm vector is rejected.
        let bad = PageRankConfig {
            warm_start: Some(vec![0.1; 3]),
            ..base
        };
        assert!(pagerank(&Source::Mem(img), &deg, &store, &bad).is_err());
    }

    #[test]
    fn backend_combine_matches_native() {
        // PJRT backend when artifacts exist, native backend otherwise —
        // the offloaded combine must reproduce the open-coded one.
        let be = crate::runtime::backend_from_env()
            .unwrap_or_else(crate::runtime::default_backend);
        let (el, img, deg) = setup(8, 2000);
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        let plain = pagerank(
            &Source::Mem(img.clone()),
            &deg,
            &store,
            &PageRankConfig {
                iterations: 5,
                ..Default::default()
            },
        )
        .unwrap()
        .0;
        let offloaded = pagerank(
            &Source::Mem(img),
            &deg,
            &store,
            &PageRankConfig {
                iterations: 5,
                combine_backend: Some(be),
                ..Default::default()
            },
        )
        .unwrap()
        .0;
        let _ = el;
        for (a, b) in plain.iter().zip(&offloaded) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
