//! Lightweight metrics: atomic counters, scoped timers and the I/O
//! accounting used by every experiment harness.
//!
//! Everything here is lock-free; the SpMM hot path only touches relaxed
//! atomics (and only when metering is enabled for a run).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonically increasing counter (bytes, requests, tasks…).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// New counter at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` (relaxed; ordering is irrelevant for pure accounting).
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Reset to zero.
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Accumulated wall-clock time in nanoseconds, safe to update from many
/// threads.
#[derive(Debug, Default)]
pub struct TimeAccum {
    nanos: AtomicU64,
}

impl TimeAccum {
    /// New accumulator at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, adding its elapsed time to the accumulator.
    #[inline]
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.add(t0.elapsed().as_nanos() as u64);
        r
    }

    /// Add `nanos` nanoseconds.
    #[inline]
    pub fn add(&self, nanos: u64) {
        self.nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Accumulated time in seconds.
    pub fn secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Reset to zero.
    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// Degraded-read accounting for a parity-striped store: how often a
/// slow-or-dead shard's extent was served by XOR reconstruction from the
/// surviving shards instead of the addressed device.
#[derive(Debug, Default)]
pub struct DegradedStats {
    /// Sub-reads served by parity reconstruction (one per bypassed or
    /// failed shard extent).
    pub degraded_reads: Counter,
    /// Bytes of shard-local data rebuilt by XOR (the reconstructed
    /// extents themselves, not the surviving-shard traffic that fed
    /// them).
    pub reconstructed_bytes: Counter,
}

impl DegradedStats {
    /// New zeroed stats block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset both counters to zero.
    pub fn reset(&self) {
        self.degraded_reads.reset();
        self.reconstructed_bytes.reset();
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} degraded reads, {} reconstructed",
            self.degraded_reads.get(),
            crate::util::human_bytes(self.reconstructed_bytes.get())
        )
    }
}

/// I/O accounting for one store (or one run): byte counts, request counts
/// and busy time, split by direction. The paper reports average throughput
/// (Fig 5b) and total data read (Fig 13 discussion); both derive from this.
#[derive(Debug, Default)]
pub struct IoStats {
    /// Bytes read through this store (or interface level).
    pub bytes_read: Counter,
    /// Bytes written through this store (or interface level).
    pub bytes_written: Counter,
    /// Read requests issued.
    pub read_reqs: Counter,
    /// Write requests issued.
    pub write_reqs: Counter,
    /// Wall time spent inside read calls (including throttle sleeps).
    pub read_time: TimeAccum,
    /// Wall time spent inside write calls (including throttle sleeps).
    pub write_time: TimeAccum,
    /// Buffer-pool hits (Fig 13 `buf-pool` ablation).
    pub pool_hits: Counter,
    /// Buffer-pool misses (fresh allocations).
    pub pool_misses: Counter,
}

impl IoStats {
    /// New zeroed stats block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Average read throughput in GB/s over a measured wall-clock window.
    pub fn read_gbps_over(&self, wall_secs: f64) -> f64 {
        if wall_secs <= 0.0 {
            return 0.0;
        }
        self.bytes_read.get() as f64 / 1e9 / wall_secs
    }

    /// Average write throughput in GB/s over a measured wall-clock window.
    pub fn write_gbps_over(&self, wall_secs: f64) -> f64 {
        if wall_secs <= 0.0 {
            return 0.0;
        }
        self.bytes_written.get() as f64 / 1e9 / wall_secs
    }

    /// Reset every counter and accumulator to zero.
    pub fn reset(&self) {
        self.bytes_read.reset();
        self.bytes_written.reset();
        self.read_reqs.reset();
        self.write_reqs.reset();
        self.read_time.reset();
        self.write_time.reset();
        self.pool_hits.reset();
        self.pool_misses.reset();
    }

    /// One-line human summary.
    pub fn summary(&self, wall_secs: f64) -> String {
        format!(
            "read {} in {} reqs ({:.2} GB/s), wrote {} in {} reqs ({:.2} GB/s), pool {}/{} hit",
            crate::util::human_bytes(self.bytes_read.get()),
            self.read_reqs.get(),
            self.read_gbps_over(wall_secs),
            crate::util::human_bytes(self.bytes_written.get()),
            self.write_reqs.get(),
            self.write_gbps_over(wall_secs),
            self.pool_hits.get(),
            self.pool_hits.get() + self.pool_misses.get(),
        )
    }
}

/// Tile-row-cache accounting (the cache level of the two-level I/O
/// stats): per-tile-row hit/miss/bypass counts plus byte flow in and out
/// of the cache. See [`crate::io::TileRowCache`] — with a warm cache,
/// `bytes_from_cache` is traffic the store never saw, which is exactly
/// the quantity the iterative-app experiments report.
#[derive(Debug, Default)]
pub struct CacheStats {
    /// Tile rows served from a resident frame.
    pub hits: Counter,
    /// Admissible tile rows that had to be read from the store.
    pub misses: Counter,
    /// Requested tile rows below the admission threshold (never cached).
    pub bypasses: Counter,
    /// Bytes served out of resident frames (store traffic avoided).
    pub bytes_from_cache: Counter,
    /// Frames inserted.
    pub insertions: Counter,
    /// Bytes inserted into frames.
    pub bytes_inserted: Counter,
    /// Frames evicted by the CLOCK sweep.
    pub evictions: Counter,
    /// Bytes reclaimed by eviction.
    pub bytes_evicted: Counter,
}

impl CacheStats {
    /// New zeroed stats block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset every counter to zero.
    pub fn reset(&self) {
        self.hits.reset();
        self.misses.reset();
        self.bypasses.reset();
        self.bytes_from_cache.reset();
        self.insertions.reset();
        self.bytes_inserted.reset();
        self.evictions.reset();
        self.bytes_evicted.reset();
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "cache {}/{} row hits ({} bypassed), {} served, {} evicted",
            self.hits.get(),
            self.hits.get() + self.misses.get(),
            self.bypasses.get(),
            crate::util::human_bytes(self.bytes_from_cache.get()),
            crate::util::human_bytes(self.bytes_evicted.get()),
        )
    }
}

/// Per-op accounting of one fused streaming pass (the op level of the
/// stats stack, above the cache and store levels): every op of a
/// [`crate::spmm::StreamPass`] gets one accumulator shared by all
/// workers, summed into [`crate::spmm::OpStats`] when the pass ends.
#[derive(Debug, Default)]
pub struct OpAccum {
    /// Time inside this op's tile kernels, summed over workers.
    pub kernel_time: TimeAccum,
    /// Time in the op's end-of-pass reduction (transpose partial merge
    /// plus reduce-time hooks; forward ops never touch it).
    pub reduce_time: TimeAccum,
    /// Output rows finalized for this op.
    pub rows_out: Counter,
}

impl OpAccum {
    /// New zeroed accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset every figure to zero.
    pub fn reset(&self) {
        self.kernel_time.reset();
        self.reduce_time.reset();
        self.rows_out.reset();
    }
}

/// A lock-free running maximum (high-water marks: batch occupancy, queue
/// depth). `observe` is a CAS loop like [`MemStats`]'s peak update.
#[derive(Debug, Default)]
pub struct MaxGauge {
    v: AtomicU64,
}

impl MaxGauge {
    /// New gauge at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Raise the gauge to `x` if `x` exceeds the current maximum.
    pub fn observe(&self, x: u64) {
        let mut cur = self.v.load(Ordering::Relaxed);
        while x > cur {
            match self
                .v
                .compare_exchange_weak(cur, x, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    /// Current maximum.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Reset to zero.
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Request-batching accounting for the serving coordinator (the
/// ride-sharing level of the stats stack, above the op level): how many
/// shared sweeps ran, how many riders they carried, and how many sparse
/// bytes the sharing amortized away relative to one-engine-call-per-
/// request serving. See [`crate::coordinator::batcher`].
#[derive(Debug, Default)]
pub struct BatchStats {
    /// Streaming passes dispatched by the batcher.
    pub passes: Counter,
    /// Passes that carried two or more riders (actual sharing happened).
    pub shared_passes: Counter,
    /// Requests served (summed over passes).
    pub riders: Counter,
    /// Highest riders-in-one-pass observed.
    pub occupancy_max: MaxGauge,
    /// Logical sparse bytes the shared sweeps actually read.
    pub swept_bytes: Counter,
    /// Logical sparse bytes a per-request engine would have read for the
    /// same requests (pass bytes × riders). `serial_equiv / swept` is the
    /// amortization factor the batcher bought.
    pub serial_equiv_bytes: Counter,
    /// Wall time requests spent queued before their pass started.
    pub queue_wait: TimeAccum,
}

impl BatchStats {
    /// New zeroed stats block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mean riders per pass (0 when no pass ran).
    pub fn mean_occupancy(&self) -> f64 {
        let p = self.passes.get();
        if p == 0 {
            return 0.0;
        }
        self.riders.get() as f64 / p as f64
    }

    /// Sparse-byte amortization factor: serial-equivalent bytes over
    /// bytes actually swept (1.0 when nothing was shared or read).
    pub fn amortization(&self) -> f64 {
        let swept = self.swept_bytes.get();
        if swept == 0 {
            return 1.0;
        }
        self.serial_equiv_bytes.get() as f64 / swept as f64
    }

    /// Reset every figure to zero.
    pub fn reset(&self) {
        self.passes.reset();
        self.shared_passes.reset();
        self.riders.reset();
        self.occupancy_max.reset();
        self.swept_bytes.reset();
        self.serial_equiv_bytes.reset();
        self.queue_wait.reset();
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} riders over {} passes ({} shared, occupancy ≤{}, mean {:.2}), \
             swept {} for a {}-worth of requests ({:.2}x amortized)",
            self.riders.get(),
            self.passes.get(),
            self.shared_passes.get(),
            self.occupancy_max.get(),
            self.mean_occupancy(),
            crate::util::human_bytes(self.swept_bytes.get()),
            crate::util::human_bytes(self.serial_equiv_bytes.get()),
            self.amortization(),
        )
    }
}

/// A simple stopwatch for benchmark harnesses.
#[derive(Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { t0: Instant::now() }
    }

    /// Seconds elapsed since start (or the last restart).
    pub fn secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Return the elapsed seconds and start a new interval.
    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.t0 = Instant::now();
        s
    }
}

/// Peak/current memory accounting used by the `MemBudget` coordinator and
/// the Fig 8 memory-consumption experiment. Tracks logical allocations the
/// engine *admits*, not RSS: the paper's memory-capacity effects are policy
/// decisions driven by sizes (see DESIGN.md substitutions).
#[derive(Debug, Default)]
pub struct MemStats {
    current: AtomicU64,
    peak: AtomicU64,
}

impl MemStats {
    /// New zeroed accounting.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an allocation of `bytes`; updates the peak watermark.
    pub fn alloc(&self, bytes: u64) {
        let cur = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        // Lock-free peak update.
        let mut peak = self.peak.load(Ordering::Relaxed);
        while cur > peak {
            match self.peak.compare_exchange_weak(
                peak,
                cur,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    /// Record a free of `bytes`.
    pub fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Bytes currently admitted.
    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    /// Peak watermark of admitted bytes.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Reset both figures to zero.
    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_concurrent() {
        let c = Arc::new(Counter::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn time_accum_runs_closure() {
        let t = TimeAccum::new();
        let x = t.time(|| 2 + 2);
        assert_eq!(x, 4);
        assert!(t.secs() >= 0.0);
    }

    #[test]
    fn mem_peak_tracks_watermark() {
        let m = MemStats::new();
        m.alloc(100);
        m.alloc(50);
        m.free(120);
        m.alloc(10);
        assert_eq!(m.current(), 40);
        assert_eq!(m.peak(), 150);
    }

    #[test]
    fn op_accum_accumulates_and_resets() {
        let a = OpAccum::new();
        a.kernel_time.add(2_000_000_000);
        a.reduce_time.add(500_000_000);
        a.rows_out.add(128);
        assert!((a.kernel_time.secs() - 2.0).abs() < 1e-9);
        assert!((a.reduce_time.secs() - 0.5).abs() < 1e-9);
        assert_eq!(a.rows_out.get(), 128);
        a.reset();
        assert_eq!(a.rows_out.get(), 0);
        assert_eq!(a.kernel_time.secs(), 0.0);
    }

    #[test]
    fn max_gauge_concurrent_keeps_maximum() {
        let g = Arc::new(MaxGauge::new());
        let hs: Vec<_> = (0..6)
            .map(|t| {
                let g = g.clone();
                std::thread::spawn(move || {
                    for i in 0..2000u64 {
                        g.observe(t * 2000 + i);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(g.get(), 5 * 2000 + 1999);
        g.reset();
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn batch_stats_amortization_and_occupancy() {
        let b = BatchStats::new();
        assert_eq!(b.amortization(), 1.0);
        assert_eq!(b.mean_occupancy(), 0.0);
        // Pass 1: 4 riders sharing a 100-byte sweep.
        b.passes.inc();
        b.shared_passes.inc();
        b.riders.add(4);
        b.occupancy_max.observe(4);
        b.swept_bytes.add(100);
        b.serial_equiv_bytes.add(400);
        // Pass 2: a solo rider.
        b.passes.inc();
        b.riders.add(1);
        b.occupancy_max.observe(1);
        b.swept_bytes.add(100);
        b.serial_equiv_bytes.add(100);
        assert_eq!(b.occupancy_max.get(), 4);
        assert!((b.mean_occupancy() - 2.5).abs() < 1e-12);
        assert!((b.amortization() - 2.5).abs() < 1e-12);
        assert_eq!(b.shared_passes.get(), 1);
        b.reset();
        assert_eq!(b.riders.get(), 0);
        assert_eq!(b.amortization(), 1.0);
    }

    #[test]
    fn io_stats_throughput() {
        let s = IoStats::new();
        s.bytes_read.add(2_000_000_000);
        assert!((s.read_gbps_over(1.0) - 2.0).abs() < 1e-9);
        assert_eq!(s.read_gbps_over(0.0), 0.0);
    }

    #[test]
    fn mem_peak_concurrent() {
        let m = Arc::new(MemStats::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.alloc(10);
                        m.free(10);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.current(), 0);
        assert!(m.peak() >= 10);
    }
}
