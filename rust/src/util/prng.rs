//! Deterministic pseudo-random number generators.
//!
//! Graph generation and sampling must be reproducible across runs and
//! platforms, so we carry our own small PRNGs instead of depending on an
//! external crate: [`SplitMix64`] for seeding and cheap streams, and
//! [`Xoshiro256`] (xoshiro256**) as the general-purpose generator.

/// SplitMix64: tiny, fast, passes BigCrush when used as a 64-bit stream.
/// Primarily used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** by Blackman & Vigna — the workhorse PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism).
    pub fn next_gaussian(&mut self) -> f64 {
        // Draw u in (0,1] to avoid log(0).
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Xoshiro256::new(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.next_gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }
}
