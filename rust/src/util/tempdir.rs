//! Self-cleaning temporary directories (offline replacement for the
//! `tempfile` crate, used by tests and short-lived stores).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static NEXT: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed (recursively) on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

/// Create a fresh unique temporary directory.
pub fn tempdir() -> TempDir {
    let base = std::env::temp_dir();
    loop {
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos();
        let path = base.join(format!("semspmm-{pid}-{t}-{n}"));
        match std::fs::create_dir(&path) {
            Ok(()) => return TempDir { path },
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
            Err(e) => panic!("cannot create temp dir: {e}"),
        }
    }
}

impl TempDir {
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_and_cleaned() {
        let p1;
        {
            let d1 = tempdir();
            let d2 = tempdir();
            assert_ne!(d1.path(), d2.path());
            assert!(d1.path().is_dir());
            std::fs::write(d1.path().join("f"), b"x").unwrap();
            p1 = d1.path().to_path_buf();
        }
        assert!(!p1.exists());
    }
}
