//! A minimal property-testing helper (offline replacement for the
//! `proptest` crate).
//!
//! [`check`] runs a property over many deterministic random cases; on
//! failure it retries with smaller size parameters (a lightweight form of
//! shrinking) and reports the seed so the case can be replayed exactly.

use super::prng::Xoshiro256;

/// Controls how inputs are generated for one case.
#[derive(Debug, Clone)]
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in `[0.0, 1.0]`; shrinking lowers it.
    pub size: f64,
    /// Case seed (printed on failure for replay).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Xoshiro256::new(seed),
            size,
            seed,
        }
    }

    /// Uniform usize in `[lo, hi]` scaled by the current size hint:
    /// shrunk cases draw closer to `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).ceil() as usize;
        lo + self.rng.below_usize(scaled.max(1).min(span + 1).max(1))
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A vector of `n` items produced by `f`.
    pub fn vec_of<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Run `prop` over `cases` deterministic random cases. On failure, retry
/// the failing seed with progressively smaller sizes to find a smaller
/// counterexample, then panic with the seed and message.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    // Environment override for quick local sweeps.
    let cases = std::env::var("SEM_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        let seed = 0x5EED_0000_0000 ^ (case.wrapping_mul(0x9E37_79B9));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-run the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut best = (1.0f64, msg);
            for k in 1..=8 {
                let size = 1.0 / (1 << k) as f64;
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    best = (size, m);
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={:.4}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 50, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_failures() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        use std::cell::RefCell;
        let tape1 = RefCell::new(Vec::new());
        let tape2 = RefCell::new(Vec::new());
        check("record1", 3, |g| {
            tape1.borrow_mut().push(g.u64());
            Ok(())
        });
        check("record2", 3, |g| {
            tape2.borrow_mut().push(g.u64());
            Ok(())
        });
        assert_eq!(tape1.into_inner(), tape2.into_inner());
    }
}
