//! Cache-line-aligned buffers for the SIMD fast paths.
//!
//! [`AlignedBuf<T>`] is a growable buffer whose first live element always
//! sits on a 64-byte boundary, so vectorized kernels see cache-line
//! aligned dense-row panels and I/O buffers. The alignment is achieved
//! in **safe Rust** by over-allocating a plain `Vec<T>` with one cache
//! line of slack and exposing the aligned window `[off, off + len)`
//! through `Deref<Target = [T]>` — no `Layout` juggling, no custom
//! allocator, and reallocation (which may move the backing storage)
//! simply recomputes the offset.
//!
//! The alignment is a performance contract, not a safety one: the SIMD
//! kernels use unaligned loads and stay correct on any slice; aligned
//! panels just avoid split-line traffic on the hot gather/scatter loops.

use std::ops::{Deref, DerefMut};

/// Target alignment in bytes (one x86/aarch64 cache line, and ≥ the
/// widest vector the kernels use).
pub const ALIGN: usize = 64;

/// A `Vec`-backed buffer whose live window starts 64-byte aligned.
pub struct AlignedBuf<T> {
    /// Backing storage, over-allocated by one line of slack elements.
    buf: Vec<T>,
    /// Elements to skip so `buf[off]` is 64-byte aligned.
    off: usize,
    /// Live length in elements.
    len: usize,
}

impl<T: Copy + Default> AlignedBuf<T> {
    /// Slack elements needed to guarantee an aligned window exists.
    #[inline]
    fn slack() -> usize {
        // T is f32/u8 here: size divides ALIGN, so ALIGN/size extra
        // elements always contain an aligned start.
        debug_assert!(ALIGN % std::mem::size_of::<T>() == 0);
        ALIGN / std::mem::size_of::<T>()
    }

    /// Offset (in elements) of the first 64-byte-aligned element.
    #[inline]
    fn align_off(ptr: *const T) -> usize {
        let addr = ptr as usize;
        let rem = addr % ALIGN;
        if rem == 0 {
            0
        } else {
            (ALIGN - rem) / std::mem::size_of::<T>()
        }
    }

    /// A zero-filled aligned buffer of `len` elements.
    pub fn zeroed(len: usize) -> AlignedBuf<T> {
        let mut b = AlignedBuf {
            buf: Vec::new(),
            off: 0,
            len: 0,
        };
        b.resize_zeroed(len);
        b
    }

    /// An empty buffer with room for `cap` elements (plus slack) so the
    /// first `resize_zeroed(<= cap)` does not reallocate.
    pub fn with_capacity(cap: usize) -> AlignedBuf<T> {
        let mut buf = Vec::with_capacity(cap + Self::slack());
        let off = Self::align_off(buf.as_ptr());
        buf.resize(off, T::default());
        AlignedBuf { buf, off, len: 0 }
    }

    /// An aligned copy of `src`.
    pub fn from_slice(src: &[T]) -> AlignedBuf<T> {
        let mut b = Self::zeroed(src.len());
        b.as_mut_slice().copy_from_slice(src);
        b
    }

    /// Resize the live window to `len` elements. Newly exposed contents
    /// are unspecified (zero on a fresh buffer, stale bytes on a reused
    /// one) — exactly the pool-buffer contract the I/O engine relies on:
    /// every byte is overwritten by the read that claims the buffer.
    ///
    /// A reallocation (or a fresh `Vec` whose base moved) may change the
    /// aligned offset; the window is recomputed, so the alignment holds
    /// after every call.
    pub fn resize_zeroed(&mut self, len: usize) {
        let need = len + Self::slack();
        if self.buf.len() < need {
            self.buf.resize(need, T::default());
        }
        self.off = Self::align_off(self.buf.as_ptr());
        self.len = len;
    }

    /// The live window as a slice (starts 64-byte aligned).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.buf[self.off..self.off + self.len]
    }

    /// The live window as a mutable slice (starts 64-byte aligned).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.buf[self.off..self.off + self.len]
    }

    /// Live length in elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the live window is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of backing storage actually allocated (slack included) —
    /// what a pool's retained-byte accounting must charge.
    #[inline]
    pub fn capacity_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<T>()
    }

    /// Fill the live window with `v`.
    pub fn fill(&mut self, v: T) {
        self.as_mut_slice().fill(v);
    }
}

impl<T: Copy + Default> Deref for AlignedBuf<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy + Default> DerefMut for AlignedBuf<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Copy + Default> Clone for AlignedBuf<T> {
    fn clone(&self) -> Self {
        Self::from_slice(self.as_slice())
    }
}

impl<T: Copy + Default> Default for AlignedBuf<T> {
    fn default() -> Self {
        Self::zeroed(0)
    }
}

impl<T: Copy + Default + std::fmt::Debug> std::fmt::Debug for AlignedBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuf")
            .field("len", &self.len)
            .field("aligned", &(self.as_ptr() as usize % ALIGN == 0))
            .finish()
    }
}

impl<T: Copy + Default> From<Vec<T>> for AlignedBuf<T> {
    fn from(v: Vec<T>) -> Self {
        Self::from_slice(&v)
    }
}

impl<T: Copy + Default + PartialEq> PartialEq for AlignedBuf<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_is_aligned_for_f32_and_u8() {
        for len in [0usize, 1, 7, 64, 1000, 16 * 1024] {
            let b: AlignedBuf<f32> = AlignedBuf::zeroed(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "f32 len={len}");
            assert!(b.iter().all(|&x| x == 0.0));
            let b: AlignedBuf<u8> = AlignedBuf::zeroed(len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "u8 len={len}");
        }
    }

    #[test]
    fn resize_keeps_alignment_across_reallocs() {
        let mut b: AlignedBuf<u8> = AlignedBuf::zeroed(8);
        for len in [16usize, 1000, 64 * 1024, 100, 1 << 20] {
            b.resize_zeroed(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "len={len}");
        }
    }

    #[test]
    fn clone_and_from_slice_preserve_contents() {
        let src: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let a = AlignedBuf::from_slice(&src);
        assert_eq!(&a[..], &src[..]);
        let b = a.clone();
        assert_eq!(&b[..], &src[..]);
        assert_eq!(b.as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn deref_mut_writes_stick() {
        let mut b: AlignedBuf<f32> = AlignedBuf::zeroed(10);
        b[3] = 7.5;
        b.as_mut_slice()[4] = 1.25;
        assert_eq!(b[3], 7.5);
        assert_eq!(b.as_slice()[4], 1.25);
        b.fill(2.0);
        assert!(b.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn with_capacity_then_resize_does_not_move() {
        let mut b: AlignedBuf<u8> = AlignedBuf::with_capacity(4096);
        assert!(b.is_empty());
        b.resize_zeroed(4096);
        assert_eq!(b.len(), 4096);
        assert_eq!(b.as_ptr() as usize % ALIGN, 0);
        assert!(b.capacity_bytes() >= 4096);
    }
}
