//! Small shared utilities: deterministic PRNG, bit manipulation, human-
//! readable sizes, and wall-clock helpers.

pub mod aligned;
pub mod prng;
pub mod proptest;
pub mod tempdir;

pub use aligned::AlignedBuf;
pub use prng::SplitMix64;
pub use tempdir::{tempdir, TempDir};
pub use prng::Xoshiro256;

/// Round `x` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceil division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Smallest power of two `>= x` (for `x >= 1`).
#[inline]
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Format a byte count as a human-readable string (KiB/MiB/GiB).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds with adaptive precision.
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn pow2() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(16), 16);
        assert_eq!(next_pow2(17), 32);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn humanize_secs() {
        assert!(human_secs(0.0000005).ends_with("us"));
        assert!(human_secs(0.005).ends_with("ms"));
        assert!(human_secs(2.5).ends_with("s"));
    }
}
