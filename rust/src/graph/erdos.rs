//! Erdős–Rényi G(n, m) generator — a no-structure control used by tests
//! and micro-benchmarks (uniform degrees, no clustering, no skew).

use super::EdgeList;
use crate::util::Xoshiro256;
use crate::VertexId;

/// Generate a G(n, m)-style graph by sampling `num_edges` endpoint pairs
/// uniformly (duplicates/self-loops removed afterwards).
pub fn generate(num_verts: usize, num_edges: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::new(num_verts);
    el.edges.reserve(num_edges);
    for _ in 0..num_edges {
        let r = rng.below_usize(num_verts) as VertexId;
        let c = rng.below_usize(num_verts) as VertexId;
        el.edges.push((r, c));
    }
    el.dedup();
    el
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic() {
        let g = generate(1000, 10_000, 1);
        assert_eq!(g.num_verts, 1000);
        assert!(g.num_edges() > 9_000);
        for &(r, c) in &g.edges {
            assert!((r as usize) < 1000 && (c as usize) < 1000);
            assert_ne!(r, c);
        }
    }

    #[test]
    fn degrees_are_balanced() {
        let g = generate(1000, 50_000, 2);
        let deg = g.row_degrees();
        let mean = g.num_edges() as f64 / 1000.0;
        let max = *deg.iter().max().unwrap() as f64;
        // Uniform sampling: max degree within ~3x of mean at this density.
        assert!(max < 3.0 * mean, "max={max} mean={mean}");
    }
}
