//! Stochastic block model generator (Fig 6 study).
//!
//! The paper uses SBM graphs with 100M vertices / 3B edges, varying the
//! number of clusters and the ratio of edges inside vs. outside clusters
//! (IN/OUT ∈ {1, 4, 16}), with vertices either ordered by cluster
//! ("clustered") or randomly permuted ("unclustered"). Cluster-ordered
//! vertices give SpMV data locality; random order destroys it. We generate
//! by sampling each edge's endpoint-cluster pair first (in-cluster with
//! probability IN/(IN+OUT)), then uniform endpoints — an efficient sampler
//! equivalent to the dense two-block-probability SBM at this sparsity.

use super::EdgeList;
use crate::util::Xoshiro256;
use crate::VertexId;

/// SBM parameters.
#[derive(Debug, Clone, Copy)]
pub struct SbmParams {
    pub num_verts: usize,
    pub num_edges: usize,
    pub num_clusters: usize,
    /// Ratio of within-cluster to between-cluster edges (the paper's
    /// IN/OUT knob). `in_out = f64::INFINITY` puts every edge in-cluster.
    pub in_out: f64,
    /// If false, relabel vertices with a random permutation after
    /// generation ("unclustered" ordering in Fig 6).
    pub clustered_order: bool,
}

/// Generate an (undirected, symmetrized) SBM graph.
pub fn generate(p: SbmParams, seed: u64) -> EdgeList {
    assert!(p.num_clusters >= 1 && p.num_clusters <= p.num_verts);
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::new(p.num_verts);
    el.edges.reserve(p.num_edges);
    let csize = p.num_verts / p.num_clusters;
    let p_in = if p.in_out.is_infinite() {
        1.0
    } else {
        p.in_out / (p.in_out + 1.0)
    };
    // Sample directed pairs; symmetrize at the end.
    for _ in 0..p.num_edges / 2 {
        let kc = rng.below_usize(p.num_clusters);
        let base = kc * csize;
        let span = if kc == p.num_clusters - 1 {
            p.num_verts - base
        } else {
            csize
        };
        let u = (base + rng.below_usize(span)) as VertexId;
        let v = if rng.next_f64() < p_in {
            // in-cluster partner
            (base + rng.below_usize(span)) as VertexId
        } else {
            // out-of-cluster partner, uniform over all vertices
            rng.below_usize(p.num_verts) as VertexId
        };
        el.edges.push((u, v));
    }
    el.symmetrize();
    if !p.clustered_order {
        el.scramble_order(seed ^ 0xDEAD_BEEF);
        el.dedup();
    }
    el
}

/// Fraction of edges whose endpoints fall in the same (contiguous-range)
/// cluster under the clustered labelling — used by tests and the Fig 6
/// harness to verify the generator hits the requested IN/OUT ratio.
pub fn in_cluster_fraction(el: &EdgeList, num_clusters: usize) -> f64 {
    let csize = el.num_verts / num_clusters;
    if el.edges.is_empty() {
        return 0.0;
    }
    let cluster_of = |v: VertexId| ((v as usize) / csize).min(num_clusters - 1);
    let inside = el
        .edges
        .iter()
        .filter(|&&(r, c)| cluster_of(r) == cluster_of(c))
        .count();
    inside as f64 / el.num_edges() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(num_clusters: usize, in_out: f64, clustered: bool) -> SbmParams {
        SbmParams {
            num_verts: 10_000,
            num_edges: 200_000,
            num_clusters,
            in_out,
            clustered_order: clustered,
        }
    }

    #[test]
    fn in_out_ratio_respected() {
        // IN/OUT = 4 → ~80% of sampled partners in-cluster (plus the
        // uniform fallback occasionally landing in-cluster).
        let g = generate(params(100, 4.0, true), 42);
        let f = in_cluster_fraction(&g, 100);
        assert!((0.75..0.9).contains(&f), "in-cluster fraction {f}");
    }

    #[test]
    fn high_in_out_is_nearly_block_diagonal() {
        let g = generate(params(10, f64::INFINITY, true), 1);
        let f = in_cluster_fraction(&g, 10);
        assert!(f > 0.999, "fraction {f}");
    }

    #[test]
    fn unclustered_destroys_locality() {
        let gc = generate(params(100, 16.0, true), 5);
        let gu = generate(params(100, 16.0, false), 5);
        let fc = in_cluster_fraction(&gc, 100);
        let fu = in_cluster_fraction(&gu, 100);
        assert!(fc > 0.9);
        // After a random permutation, the chance two endpoints land in the
        // same of 100 clusters is ~1%.
        assert!(fu < 0.05, "unclustered fraction {fu}");
    }

    #[test]
    fn symmetric() {
        let g = generate(params(10, 4.0, true), 9);
        use std::collections::HashSet;
        let set: HashSet<_> = g.edges.iter().copied().collect();
        for &(r, c) in &g.edges {
            assert!(set.contains(&(c, r)));
        }
    }
}
