//! Graph generation and edge-list handling.
//!
//! The paper evaluates on Twitter, Friendster, the Web Data Commons page
//! graph and two R-MAT graphs (Table 1), plus stochastic-block-model graphs
//! for the Fig 6 clustering study. We cannot ship those datasets, so this
//! module provides generators whose *structural* properties match them
//! (power-law degrees, near-random connectivity, tunable cluster structure)
//! plus a [`registry`] of scaled-down stand-ins (see DESIGN.md).

pub mod erdos;
pub mod registry;
pub mod rmat;
pub mod sbm;

use crate::util::Xoshiro256;
use crate::VertexId;

/// An unweighted directed edge list. The adjacency matrix of the graph is
/// `A[dst][src] = 1` when interpreting SpMV as pull-style propagation; the
/// format layer is orientation-agnostic (it just stores (row, col) pairs).
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    /// Number of vertices (matrix dimension).
    pub num_verts: usize,
    /// (row, col) pairs; may contain duplicates until [`Self::dedup`].
    pub edges: Vec<(VertexId, VertexId)>,
}

impl EdgeList {
    pub fn new(num_verts: usize) -> Self {
        Self {
            num_verts,
            edges: Vec::new(),
        }
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Sort by (row, col) and remove duplicate edges and self-loops.
    pub fn dedup(&mut self) {
        self.edges.retain(|&(r, c)| r != c);
        self.edges.sort_unstable();
        self.edges.dedup();
    }

    /// Make the graph undirected by mirroring every edge, then dedup.
    pub fn symmetrize(&mut self) {
        let mirrored: Vec<_> = self.edges.iter().map(|&(r, c)| (c, r)).collect();
        self.edges.extend(mirrored);
        self.dedup();
    }

    /// Transpose (swap row/col on every edge).
    pub fn transpose(&self) -> EdgeList {
        EdgeList {
            num_verts: self.num_verts,
            edges: self.edges.iter().map(|&(r, c)| (c, r)).collect(),
        }
    }

    /// Out-degree of every vertex, interpreting `(row, col)` as `col → row`
    /// (i.e. column = source). This matches `A x` propagating values from
    /// sources (columns) to destinations (rows), the PageRank convention.
    pub fn col_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_verts];
        for &(_, c) in &self.edges {
            d[c as usize] += 1;
        }
        d
    }

    /// In-degree per row.
    pub fn row_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_verts];
        for &(r, _) in &self.edges {
            d[r as usize] += 1;
        }
        d
    }

    /// Relabel vertices with a random permutation — destroys any clustered
    /// ordering (the "unclustered" configuration of Fig 6).
    pub fn scramble_order(&mut self, seed: u64) {
        let mut perm: Vec<VertexId> = (0..self.num_verts as VertexId).collect();
        let mut rng = Xoshiro256::new(seed);
        rng.shuffle(&mut perm);
        for e in &mut self.edges {
            e.0 = perm[e.0 as usize];
            e.1 = perm[e.1 as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> EdgeList {
        EdgeList {
            num_verts: 4,
            edges: vec![(0, 1), (1, 2), (1, 2), (2, 2), (3, 0)],
        }
    }

    #[test]
    fn dedup_removes_dupes_and_loops() {
        let mut e = small();
        e.dedup();
        assert_eq!(e.edges, vec![(0, 1), (1, 2), (3, 0)]);
    }

    #[test]
    fn symmetrize_mirrors() {
        let mut e = small();
        e.symmetrize();
        for &(r, c) in e.edges.clone().iter() {
            assert!(e.edges.contains(&(c, r)));
        }
    }

    #[test]
    fn degrees() {
        let mut e = small();
        e.dedup();
        assert_eq!(e.col_degrees(), vec![1, 1, 1, 0]);
        assert_eq!(e.row_degrees(), vec![1, 1, 0, 1]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut e = small();
        e.dedup();
        let tt = e.transpose().transpose();
        assert_eq!(tt.edges, e.edges);
    }

    #[test]
    fn scramble_preserves_edge_count_and_degree_multiset() {
        let mut e = small();
        e.dedup();
        let before = e.num_edges();
        let mut deg_before = e.col_degrees();
        deg_before.sort_unstable();
        e.scramble_order(99);
        assert_eq!(e.num_edges(), before);
        let mut deg_after = e.col_degrees();
        deg_after.sort_unstable();
        assert_eq!(deg_before, deg_after);
    }
}
