//! Dataset registry: scaled-down stand-ins for the paper's Table 1.
//!
//! | Paper dataset | Vertices | Edges | Stand-in here |
//! |---|---|---|---|
//! | Twitter      | 42M   | 1.5B  | R-MAT, edge factor 36, scrambled order |
//! | Friendster   | 65M   | 1.7B  | R-MAT, factor 26, undirected, scrambled |
//! | Page graph   | 3.4B  | 129B  | SBM (1K clusters, IN/OUT=16), clustered order, power-law overlay |
//! | RMAT-40      | 100M  | 3.7B  | R-MAT, factor 37 |
//! | RMAT-160     | 100M  | 14B   | R-MAT, factor 140 |
//!
//! Each stand-in preserves the property the paper's experiments depend on:
//! power-law degree skew (load imbalance), near-random connectivity (cache
//! misses) and — for the page graph — a clustered vertex ordering, which is
//! what makes SpMV on it less memory-bound and hence more I/O-bound in SEM
//! (§5.1). Absolute sizes are scaled by `scale` (log2 #vertices); the
//! default bench profile uses scale 17–18 so every figure regenerates in
//! minutes on one machine.

use super::{rmat, sbm, EdgeList};

/// How vertices of a dataset are connected/ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Structure {
    /// R-MAT power-law, vertices randomly relabelled (social networks).
    PowerLawScrambled,
    /// R-MAT power-law, natural recursive ordering.
    PowerLawNatural,
    /// SBM with a clustered vertex ordering (web page graph).
    ClusteredWeb,
}

/// A named dataset configuration.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Registry name (paper dataset it stands in for).
    pub name: &'static str,
    /// log2 of the vertex count.
    pub scale: u32,
    /// Edges per vertex (paper's ratio preserved).
    pub edge_factor: usize,
    /// Whether the paper's dataset is directed.
    pub directed: bool,
    pub structure: Structure,
    /// Generator seed (fixed for reproducibility).
    pub seed: u64,
}

impl DatasetSpec {
    /// Number of vertices.
    pub fn num_verts(&self) -> usize {
        1usize << self.scale
    }

    /// Target number of generated edges (pre-dedup).
    pub fn target_edges(&self) -> usize {
        self.num_verts() * self.edge_factor
    }

    /// Materialize the edge list.
    pub fn build(&self) -> EdgeList {
        let mut el = match self.structure {
            Structure::PowerLawScrambled | Structure::PowerLawNatural => rmat::generate(
                self.scale,
                self.target_edges(),
                rmat::RmatParams::default(),
                self.seed,
            ),
            Structure::ClusteredWeb => sbm::generate(
                sbm::SbmParams {
                    num_verts: self.num_verts(),
                    num_edges: self.target_edges(),
                    num_clusters: (self.num_verts() / 256).max(1),
                    in_out: 16.0,
                    clustered_order: true,
                },
                self.seed,
            ),
        };
        if matches!(self.structure, Structure::PowerLawScrambled) {
            el.scramble_order(self.seed ^ 0x5C5C_5C5C);
            el.dedup();
        }
        if !self.directed {
            el.symmetrize();
        }
        el
    }

    /// A reduced copy for fast tests (shrinks both scale and edge factor).
    pub fn shrunk(&self, scale: u32) -> DatasetSpec {
        DatasetSpec {
            scale,
            ..self.clone()
        }
    }
}

/// The bench-profile registry (scale 17–18 ≈ 131–262K vertices).
pub fn registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "twitter",
            scale: 17,
            edge_factor: 36,
            directed: true,
            structure: Structure::PowerLawScrambled,
            seed: 0x7717_7E01,
        },
        DatasetSpec {
            name: "friendster",
            scale: 17,
            edge_factor: 26,
            directed: false,
            structure: Structure::PowerLawScrambled,
            seed: 0xF21E_4D02,
        },
        DatasetSpec {
            name: "page",
            scale: 18,
            edge_factor: 38,
            directed: true,
            structure: Structure::ClusteredWeb,
            seed: 0x9A6E_0003,
        },
        DatasetSpec {
            name: "rmat-40",
            scale: 17,
            edge_factor: 37,
            directed: true,
            structure: Structure::PowerLawNatural,
            seed: 0x2A40_0004,
        },
        DatasetSpec {
            name: "rmat-160",
            scale: 17,
            edge_factor: 140,
            directed: true,
            structure: Structure::PowerLawNatural,
            seed: 0x2A16_0005,
        },
    ]
}

/// Look a dataset up by name; `None` if unknown.
pub fn by_name(name: &str) -> Option<DatasetSpec> {
    registry().into_iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique() {
        let r = registry();
        let mut names: Vec<_> = r.iter().map(|d| d.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), r.len());
    }

    #[test]
    fn lookup() {
        assert!(by_name("twitter").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn shrunk_builds_quickly_and_correctly() {
        for spec in registry() {
            let small = spec.shrunk(10);
            let el = small.build();
            assert_eq!(el.num_verts, 1024);
            assert!(el.num_edges() > 0);
            for &(r, c) in &el.edges {
                assert!((r as usize) < 1024 && (c as usize) < 1024);
            }
            if !small.directed {
                // undirected stand-ins are symmetric
                use std::collections::HashSet;
                let s: HashSet<_> = el.edges.iter().copied().collect();
                for &(r, c) in &el.edges {
                    assert!(s.contains(&(c, r)), "{}: missing mirror", small.name);
                }
            }
        }
    }

    #[test]
    fn page_standin_is_clustered() {
        let spec = by_name("page").unwrap().shrunk(12);
        let el = spec.build();
        let f = super::super::sbm::in_cluster_fraction(&el, (el.num_verts / 256).max(1));
        assert!(f > 0.5, "page stand-in should be clustered, got {f}");
    }
}
