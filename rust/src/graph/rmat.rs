//! R-MAT recursive-matrix graph generator (Chakrabarti et al., SDM 2004).
//!
//! The paper generates RMAT-40 / RMAT-160 with the boost generator using
//! `a = 0.57, b = 0.19, c = 0.19, d = 0.05` — the Graph500 parameters —
//! which produce a power-law degree distribution and near-random vertex
//! connectivity, the two properties that stress SpMM (load imbalance and
//! CPU cache misses). We reproduce the same recursive quadrant-descent
//! sampler with per-level probability smoothing.

use super::EdgeList;
use crate::util::Xoshiro256;
use crate::VertexId;

/// R-MAT parameters. Quadrant probabilities must sum to 1.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    /// Multiplicative noise applied to (a,b,c,d) at every recursion level,
    /// as in the reference Graph500/boost implementations, to avoid exact
    /// self-similarity artifacts.
    pub noise: f64,
}

impl Default for RmatParams {
    /// The paper's parameters (footnote 1): a=0.57, b=0.19, c=0.19, d=0.05.
    fn default() -> Self {
        Self {
            a: 0.57,
            b: 0.19,
            c: 0.19,
            d: 0.05,
            noise: 0.1,
        }
    }
}

/// Generate an R-MAT graph with `2^scale` vertices and ~`num_edges` edges
/// (duplicates and self-loops removed, so the final count is slightly
/// lower — the same convention the boost generator uses).
pub fn generate(scale: u32, num_edges: usize, params: RmatParams, seed: u64) -> EdgeList {
    let n = 1usize << scale;
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::new(n);
    el.edges.reserve(num_edges);
    for _ in 0..num_edges {
        let (r, c) = sample_edge(scale, params, &mut rng);
        el.edges.push((r, c));
    }
    el.dedup();
    el
}

/// Descend `scale` levels of the recursive quadrant partition.
fn sample_edge(scale: u32, p: RmatParams, rng: &mut Xoshiro256) -> (VertexId, VertexId) {
    let mut row = 0u64;
    let mut col = 0u64;
    let (mut a, mut b, mut c, mut d) = (p.a, p.b, p.c, p.d);
    for level in 0..scale {
        let half = 1u64 << (scale - 1 - level);
        let r = rng.next_f64() * (a + b + c + d);
        if r < a {
            // top-left: nothing to add
        } else if r < a + b {
            col += half;
        } else if r < a + b + c {
            row += half;
        } else {
            row += half;
            col += half;
        }
        // Smooth the probabilities with multiplicative noise, then
        // renormalize; keeps expected values but breaks self-similarity.
        if p.noise > 0.0 {
            a *= 1.0 + p.noise * (rng.next_f64() - 0.5);
            b *= 1.0 + p.noise * (rng.next_f64() - 0.5);
            c *= 1.0 + p.noise * (rng.next_f64() - 0.5);
            d *= 1.0 + p.noise * (rng.next_f64() - 0.5);
            let s = a + b + c + d;
            a /= s;
            b /= s;
            c /= s;
            d /= s;
        }
    }
    (row as VertexId, col as VertexId)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_bounds() {
        let g = generate(10, 8_000, RmatParams::default(), 1);
        assert_eq!(g.num_verts, 1024);
        assert!(g.num_edges() > 4_000 && g.num_edges() <= 8_000);
        for &(r, c) in &g.edges {
            assert!((r as usize) < g.num_verts && (c as usize) < g.num_verts);
            assert_ne!(r, c);
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(8, 2_000, RmatParams::default(), 7);
        let b = generate(8, 2_000, RmatParams::default(), 7);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn power_law_skew() {
        // With a=0.57 the degree distribution must be heavily skewed:
        // the max degree should far exceed the mean.
        let g = generate(12, 40_000, RmatParams::default(), 3);
        let deg = g.row_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let mean = g.num_edges() as f64 / g.num_verts as f64;
        assert!(
            max > 10.0 * mean,
            "expected skew: max={max}, mean={mean:.2}"
        );
    }

    #[test]
    fn uniform_params_not_skewed_like_default() {
        let uni = RmatParams {
            a: 0.25,
            b: 0.25,
            c: 0.25,
            d: 0.25,
            noise: 0.0,
        };
        let gu = generate(12, 40_000, uni, 3);
        let gd = generate(12, 40_000, RmatParams::default(), 3);
        let max_u = *gu.row_degrees().iter().max().unwrap();
        let max_d = *gd.row_degrees().iter().max().unwrap();
        assert!(max_d > 2 * max_u, "rmat skew {max_d} vs uniform {max_u}");
    }
}
