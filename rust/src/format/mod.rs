//! Sparse matrix formats.
//!
//! * [`Csr`] — classic compressed sparse row, the interchange/baseline
//!   format (what MKL/Tpetra use; also the *input* of the paper's Table 2
//!   conversion experiment).
//! * [`scsr`] — the paper's contribution: tiles in **SCSR + COO** encoding
//!   (§3.2, Fig 1): per-tile row headers with the MSB tag, 2-byte local
//!   indices, single-entry rows in a trailing COO section.
//! * [`dcsc`] — doubly-compressed sparse column tiles (Buluç & Gilbert),
//!   the format the paper compares SCSR against (Fig 2, Fig 13).
//! * [`tiled`] — the tiled on-disk/in-memory image: a matrix cut into
//!   `t × t` cache tiles grouped in tile rows, with a tile-row index so the
//!   SEM engine can stream tile rows sequentially.
//! * [`convert`] — CSR → tiled-image conversion (Table 2).
//! * [`delta`] — sorted edge-update runs ("SEMD") and the canonical
//!   base ⊕ delta tile-row merge behind the LSM update layer
//!   ([`crate::io::delta`]).

pub mod convert;
pub mod dcsc;
pub mod delta;
pub mod scsr;
pub mod tiled;

use crate::graph::EdgeList;
use crate::VertexId;

/// Compressed sparse row. `indptr` has `nrows + 1` entries; column indices
/// within a row are sorted. `vals == None` encodes a binary matrix (graph
/// adjacency), matching the paper's graph workloads where no values are
/// stored at all.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row pointers: row `r`'s entries are `indices[indptr[r]..indptr[r+1]]`.
    pub indptr: Vec<u64>,
    /// Column indices, sorted within each row.
    pub indices: Vec<VertexId>,
    /// Per-entry values; `None` encodes a binary matrix.
    pub vals: Option<Vec<f32>>,
}

impl Csr {
    /// Build from an edge list (entries are deduplicated/sorted first if
    /// needed). Binary values.
    pub fn from_edgelist(el: &EdgeList) -> Csr {
        let mut edges = el.edges.clone();
        edges.sort_unstable();
        edges.dedup();
        Self::from_sorted_pairs(el.num_verts, el.num_verts, &edges)
    }

    /// Build from sorted, deduplicated (row, col) pairs.
    pub fn from_sorted_pairs(
        nrows: usize,
        ncols: usize,
        pairs: &[(VertexId, VertexId)],
    ) -> Csr {
        debug_assert!(pairs.windows(2).all(|w| w[0] < w[1]));
        let mut indptr = vec![0u64; nrows + 1];
        for &(r, _) in pairs {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..nrows {
            indptr[i + 1] += indptr[i];
        }
        let indices: Vec<VertexId> = pairs.iter().map(|&(_, c)| c).collect();
        Csr {
            nrows,
            ncols,
            indptr,
            indices,
            vals: None,
        }
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[VertexId] {
        &self.indices[self.indptr[r] as usize..self.indptr[r + 1] as usize]
    }

    /// Values of row `r` (only when the matrix is weighted).
    #[inline]
    pub fn row_vals(&self, r: usize) -> Option<&[f32]> {
        self.vals
            .as_ref()
            .map(|v| &v[self.indptr[r] as usize..self.indptr[r + 1] as usize])
    }

    /// Nominal in-memory footprint in bytes (for Fig 8): 8-byte indptr +
    /// 4-byte indices (+ 4-byte values when present). This is what a
    /// CSR-based library (MKL/Tpetra) must hold.
    pub fn footprint_bytes(&self) -> u64 {
        let v = if self.vals.is_some() { 4 } else { 0 };
        (self.indptr.len() * 8 + self.indices.len() * (4 + v)) as u64
    }

    /// Transpose (yields CSR of Aᵀ).
    pub fn transpose(&self) -> Csr {
        let mut pairs: Vec<(VertexId, VertexId)> = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            for &c in self.row(r) {
                pairs.push((c, r as VertexId));
            }
        }
        pairs.sort_unstable();
        let mut t = Csr::from_sorted_pairs(self.ncols, self.nrows, &pairs);
        // carry values if present
        if let Some(vals) = &self.vals {
            let mut tv = vec![0f32; self.nnz()];
            let mut cursor: Vec<u64> = t.indptr.clone();
            for r in 0..self.nrows {
                let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
                for k in s..e {
                    let c = self.indices[k] as usize;
                    tv[cursor[c] as usize] = vals[k];
                    cursor[c] += 1;
                }
            }
            t.vals = Some(tv);
        }
        t
    }

    /// Dense reference multiply: `out = A * x` for one vector (test oracle).
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut out = vec![0f32; self.nrows];
        for r in 0..self.nrows {
            let mut acc = 0f32;
            match self.row_vals(r) {
                Some(vals) => {
                    for (i, &c) in self.row(r).iter().enumerate() {
                        acc += vals[i] * x[c as usize];
                    }
                }
                None => {
                    for &c in self.row(r) {
                        acc += x[c as usize];
                    }
                }
            }
            out[r] = acc;
        }
        out
    }

    /// Dense reference multiply for a row-major dense matrix with `p`
    /// columns: `out = A * X` (test oracle; also the innermost loop of the
    /// CSR baselines).
    pub fn spmm_ref(&self, x: &[f32], p: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols * p);
        let mut out = vec![0f32; self.nrows * p];
        for r in 0..self.nrows {
            let orow = &mut out[r * p..(r + 1) * p];
            match self.row_vals(r) {
                Some(vals) => {
                    for (i, &c) in self.row(r).iter().enumerate() {
                        let xr = &x[c as usize * p..c as usize * p + p];
                        let v = vals[i];
                        for j in 0..p {
                            orow[j] += v * xr[j];
                        }
                    }
                }
                None => {
                    for &c in self.row(r) {
                        let xr = &x[c as usize * p..c as usize * p + p];
                        for j in 0..p {
                            orow[j] += xr[j];
                        }
                    }
                }
            }
        }
        out
    }
}

/// Value payload carried by a tile encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueType {
    /// Binary matrix (graph adjacency): implicit value 1.0, zero bytes.
    Binary,
    /// One little-endian f32 per non-zero.
    F32,
}

impl ValueType {
    /// Bytes each value occupies on disk (0 for binary matrices).
    pub fn bytes(&self) -> usize {
        match self {
            ValueType::Binary => 0,
            ValueType::F32 => 4,
        }
    }

    /// On-disk code of this value type.
    pub fn code(&self) -> u8 {
        match self {
            ValueType::Binary => 0,
            ValueType::F32 => 1,
        }
    }

    /// Decode an on-disk code (`None` for unknown codes).
    pub fn from_code(c: u8) -> Option<ValueType> {
        match c {
            0 => Some(ValueType::Binary),
            1 => Some(ValueType::F32),
            _ => None,
        }
    }
}

/// Tile encoding selector (the Fig 13 `SCSR` ablation toggles this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileFormat {
    /// The paper's SCSR + COO encoding ([`scsr`]).
    Scsr,
    /// Doubly-compressed sparse column ([`dcsc`]), the baseline format.
    Dcsc,
}

impl TileFormat {
    /// On-disk code of this tile format.
    pub fn code(&self) -> u8 {
        match self {
            TileFormat::Scsr => 0,
            TileFormat::Dcsc => 1,
        }
    }

    /// Decode an on-disk code (`None` for unknown codes).
    pub fn from_code(c: u8) -> Option<TileFormat> {
        match c {
            0 => Some(TileFormat::Scsr),
            1 => Some(TileFormat::Dcsc),
            _ => None,
        }
    }
}

/// The entries of one `t × t` tile in decoded (local-index) form — the
/// unit handed to tile encoders and produced by test decoders.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TileEntries {
    /// (local_row, local_col), sorted by (row, col); both `< t <= 32768`.
    pub coords: Vec<(u16, u16)>,
    /// Parallel values (empty for binary matrices).
    pub vals: Vec<f32>,
}

impl TileEntries {
    /// Number of entries in the tile.
    pub fn nnz(&self) -> usize {
        self.coords.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::erdos;

    #[test]
    fn csr_from_edgelist_roundtrip() {
        let el = EdgeList {
            num_verts: 4,
            edges: vec![(0, 1), (0, 3), (2, 0), (3, 3)],
        };
        let m = Csr::from_edgelist(&el);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0), &[1, 3]);
        assert_eq!(m.row(1), &[] as &[u32]);
        assert_eq!(m.row(2), &[0]);
        assert_eq!(m.row(3), &[3]);
    }

    #[test]
    fn spmv_ref_matches_manual() {
        let el = EdgeList {
            num_verts: 3,
            edges: vec![(0, 1), (1, 0), (1, 2), (2, 2)],
        };
        let m = Csr::from_edgelist(&el);
        let y = m.spmv_ref(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![2.0, 4.0, 3.0]);
    }

    #[test]
    fn spmm_ref_p2() {
        let el = EdgeList {
            num_verts: 2,
            edges: vec![(0, 0), (0, 1), (1, 1)],
        };
        let m = Csr::from_edgelist(&el);
        let x = vec![1.0, 10.0, 2.0, 20.0]; // rows [1,10], [2,20]
        let y = m.spmm_ref(&x, 2);
        assert_eq!(y, vec![3.0, 30.0, 2.0, 20.0]);
    }

    #[test]
    fn transpose_involution_and_values() {
        let el = erdos::generate(64, 300, 5);
        let mut m = Csr::from_edgelist(&el);
        // attach distinguishable values
        m.vals = Some((0..m.nnz()).map(|i| i as f32 + 0.5).collect());
        let tt = m.transpose().transpose();
        assert_eq!(tt.indptr, m.indptr);
        assert_eq!(tt.indices, m.indices);
        assert_eq!(tt.vals, m.vals);
    }

    #[test]
    fn transpose_spmv_consistent() {
        let el = erdos::generate(50, 400, 9);
        let m = Csr::from_edgelist(&el);
        let t = m.transpose();
        let x: Vec<f32> = (0..50).map(|i| (i % 7) as f32).collect();
        // (A x)_i == (Aᵀ)ᵀ x — compare A*x with manual via transpose twice
        assert_eq!(m.spmv_ref(&x), t.transpose().spmv_ref(&x));
    }
}
