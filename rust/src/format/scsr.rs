//! SCSR + COO tile encoding (paper §3.2, Fig 1).
//!
//! A tile is a `t × t` submatrix with `t <= 32768` so local row/column
//! indices fit in 15 bits. Rows with **two or more** non-zeros are stored
//! as SCSR: a 2-byte row header whose most-significant bit is 1 (low 15
//! bits = local row id) followed by the row's 2-byte column indices (MSB
//! 0). Rows with exactly **one** non-zero are stored behind the SCSR
//! stream as COO (row, col) pairs — same 4 bytes per entry but with no
//! end-of-row test in the inner loop (the paper's conditional-jump
//! optimization). Values, when present, trail the index data: SCSR-part
//! values in stream order, then COO-part values.
//!
//! On-disk layout of one encoded tile:
//!
//! ```text
//! u32  tile_col     column-block index of this tile inside its tile row
//! u32  nnz
//! u16  n_multi      number of rows with >= 2 entries (SCSR part)
//! u16  n_single     number of single-entry rows (COO part)
//! u16 × (n_multi + nnz_multi)   SCSR stream (headers MSB=1, cols MSB=0)
//! u16 × 2 × n_single            COO pairs (row, col)
//! f32 × nnz                      values (omitted for binary matrices)
//! ```

use super::{TileEntries, ValueType};

/// MSB tag marking a row header in the SCSR stream.
pub const ROW_TAG: u16 = 0x8000;

/// Fixed per-tile header size in bytes.
pub const TILE_HEADER: usize = 12;

/// Analytic storage size (paper's formula): `2·nnr + (2+c)·nnz` plus our
/// fixed tile header. `nnr` = non-empty rows.
pub fn analytic_size(nnr: usize, nnz: usize, vt: ValueType) -> usize {
    TILE_HEADER + 2 * nnr + (2 + vt.bytes()) * nnz
}

/// Encode one tile. `entries.coords` must be sorted by (row, col) and the
/// tile must be non-empty. Appends to `out` and returns the encoded size.
pub fn encode(tile_col: u32, entries: &TileEntries, vt: ValueType, out: &mut Vec<u8>) -> usize {
    let start = out.len();
    let nnz = entries.nnz();
    assert!(nnz > 0, "empty tiles are not stored");
    debug_assert!(entries.coords.windows(2).all(|w| w[0] < w[1]));
    if vt == ValueType::F32 {
        assert_eq!(entries.vals.len(), nnz);
    }

    // First pass: classify rows.
    let mut n_multi = 0u32;
    let mut n_single = 0u32;
    {
        let mut i = 0;
        while i < nnz {
            let r = entries.coords[i].0;
            let mut j = i + 1;
            while j < nnz && entries.coords[j].0 == r {
                j += 1;
            }
            if j - i == 1 {
                n_single += 1;
            } else {
                n_multi += 1;
            }
            i = j;
        }
    }

    out.extend_from_slice(&tile_col.to_le_bytes());
    out.extend_from_slice(&(nnz as u32).to_le_bytes());
    out.extend_from_slice(&(n_multi as u16).to_le_bytes());
    out.extend_from_slice(&(n_single as u16).to_le_bytes());

    // SCSR stream for multi-entry rows; collect value order on the side.
    let mut val_order: Vec<usize> = Vec::with_capacity(if vt == ValueType::F32 { nnz } else { 0 });
    let mut i = 0;
    while i < nnz {
        let r = entries.coords[i].0;
        let mut j = i + 1;
        while j < nnz && entries.coords[j].0 == r {
            j += 1;
        }
        if j - i >= 2 {
            debug_assert!(r < ROW_TAG);
            out.extend_from_slice(&(ROW_TAG | r).to_le_bytes());
            for k in i..j {
                let c = entries.coords[k].1;
                debug_assert!(c < ROW_TAG);
                out.extend_from_slice(&c.to_le_bytes());
                if vt == ValueType::F32 {
                    val_order.push(k);
                }
            }
        }
        i = j;
    }
    // COO section for single-entry rows.
    let mut i = 0;
    while i < nnz {
        let r = entries.coords[i].0;
        let mut j = i + 1;
        while j < nnz && entries.coords[j].0 == r {
            j += 1;
        }
        if j - i == 1 {
            out.extend_from_slice(&r.to_le_bytes());
            out.extend_from_slice(&entries.coords[i].1.to_le_bytes());
            if vt == ValueType::F32 {
                val_order.push(i);
            }
        }
        i = j;
    }
    if vt == ValueType::F32 {
        for &k in &val_order {
            out.extend_from_slice(&entries.vals[k].to_le_bytes());
        }
    }
    out.len() - start
}

/// A zero-copy view over one encoded tile.
#[derive(Debug, Clone, Copy)]
pub struct TileView<'a> {
    /// Column-block index of this tile inside its tile row.
    pub tile_col: u32,
    /// Non-zeros in the tile.
    pub nnz: usize,
    /// Rows with two or more entries (SCSR part).
    pub n_multi: usize,
    /// Single-entry rows (COO part).
    pub n_single: usize,
    /// SCSR stream bytes: `(n_multi + nnz_multi)` u16 little-endian words.
    pub scsr: &'a [u8],
    /// COO pair bytes: `2 * n_single` u16 words.
    pub coo: &'a [u8],
    /// Value bytes (`4 * nnz`, empty for binary).
    pub vals: &'a [u8],
}

/// Parse one tile at `buf[off..]`; returns the view and the offset just
/// past the tile. Panics on malformed input (images are trusted; the store
/// checksums them at a higher level).
pub fn parse(buf: &[u8], off: usize, vt: ValueType) -> (TileView<'_>, usize) {
    let tile_col = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
    let nnz = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap()) as usize;
    let n_multi = u16::from_le_bytes(buf[off + 8..off + 10].try_into().unwrap()) as usize;
    let n_single = u16::from_le_bytes(buf[off + 10..off + 12].try_into().unwrap()) as usize;
    let nnz_multi = nnz - n_single;
    let scsr_words = n_multi + nnz_multi;
    let scsr_start = off + TILE_HEADER;
    let coo_start = scsr_start + scsr_words * 2;
    let vals_start = coo_start + n_single * 4;
    let end = vals_start + nnz * vt.bytes();
    (
        TileView {
            tile_col,
            nnz,
            n_multi,
            n_single,
            scsr: &buf[scsr_start..coo_start],
            coo: &buf[coo_start..vals_start],
            vals: &buf[vals_start..end],
        },
        end,
    )
}

/// Decode a tile view back to sorted [`TileEntries`] (test/verification
/// path; the SpMM kernels consume [`TileView`] directly).
pub fn decode(view: &TileView<'_>, vt: ValueType) -> TileEntries {
    let mut e = TileEntries::default();
    let mut vals_scsr: Vec<f32> = Vec::new();
    let read_u16 = |b: &[u8], i: usize| u16::from_le_bytes([b[2 * i], b[2 * i + 1]]);
    let words = view.scsr.len() / 2;
    let mut i = 0;
    let mut vi = 0usize;
    let mut pending: Vec<((u16, u16), usize)> = Vec::new();
    let mut cur_row = 0u16;
    while i < words {
        let w = read_u16(view.scsr, i);
        if w & ROW_TAG != 0 {
            cur_row = w & !ROW_TAG;
        } else {
            pending.push(((cur_row, w), vi));
            vi += 1;
        }
        i += 1;
    }
    for k in 0..view.n_single {
        let r = read_u16(view.coo, 2 * k);
        let c = read_u16(view.coo, 2 * k + 1);
        pending.push(((r, c), vi));
        vi += 1;
    }
    if vt == ValueType::F32 {
        for k in 0..view.nnz {
            vals_scsr.push(f32::from_le_bytes(
                view.vals[4 * k..4 * k + 4].try_into().unwrap(),
            ));
        }
    }
    pending.sort_unstable_by_key(|&(rc, _)| rc);
    for (rc, orig) in pending {
        e.coords.push(rc);
        if vt == ValueType::F32 {
            e.vals.push(vals_scsr[orig]);
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_tile(t: u16, n: usize, seed: u64, weighted: bool) -> TileEntries {
        let mut rng = Xoshiro256::new(seed);
        let mut coords: Vec<(u16, u16)> = (0..n)
            .map(|_| {
                (
                    rng.below(t as u64) as u16,
                    rng.below(t as u64) as u16,
                )
            })
            .collect();
        coords.sort_unstable();
        coords.dedup();
        let vals = if weighted {
            coords.iter().map(|_| rng.next_f32() + 0.1).collect()
        } else {
            Vec::new()
        };
        TileEntries { coords, vals }
    }

    #[test]
    fn roundtrip_binary() {
        let e = random_tile(1024, 5000, 1, false);
        let mut buf = Vec::new();
        encode(7, &e, ValueType::Binary, &mut buf);
        let (view, end) = parse(&buf, 0, ValueType::Binary);
        assert_eq!(end, buf.len());
        assert_eq!(view.tile_col, 7);
        assert_eq!(view.nnz, e.nnz());
        let d = decode(&view, ValueType::Binary);
        assert_eq!(d.coords, e.coords);
    }

    #[test]
    fn roundtrip_weighted() {
        let e = random_tile(512, 2000, 2, true);
        let mut buf = Vec::new();
        encode(3, &e, ValueType::F32, &mut buf);
        let (view, _) = parse(&buf, 0, ValueType::F32);
        let d = decode(&view, ValueType::F32);
        assert_eq!(d.coords, e.coords);
        assert_eq!(d.vals, e.vals);
    }

    #[test]
    fn single_entry_rows_go_to_coo() {
        // 3 single-entry rows, 1 row with 3 entries.
        let e = TileEntries {
            coords: vec![(0, 5), (2, 1), (2, 3), (2, 9), (4, 0), (9, 9)],
            vals: vec![],
        };
        let mut buf = Vec::new();
        encode(0, &e, ValueType::Binary, &mut buf);
        let (view, _) = parse(&buf, 0, ValueType::Binary);
        assert_eq!(view.n_multi, 1);
        assert_eq!(view.n_single, 3);
        // SCSR stream = 1 header + 3 cols = 4 words.
        assert_eq!(view.scsr.len(), 8);
        assert_eq!(decode(&view, ValueType::Binary).coords, e.coords);
    }

    #[test]
    fn size_matches_analytic_formula() {
        let e = random_tile(2048, 4000, 3, false);
        let nnr = {
            let mut rows: Vec<u16> = e.coords.iter().map(|&(r, _)| r).collect();
            rows.dedup();
            rows.len()
        };
        let mut buf = Vec::new();
        let sz = encode(0, &e, ValueType::Binary, &mut buf);
        // Our stream stores 2 bytes per non-empty *multi* row header plus
        // 2 bytes per COO row id — exactly 2·nnr — plus 2 bytes per col.
        assert_eq!(sz, analytic_size(nnr, e.nnz(), ValueType::Binary));
    }

    #[test]
    fn back_to_back_tiles_parse() {
        let e1 = random_tile(256, 300, 4, false);
        let e2 = random_tile(256, 200, 5, false);
        let mut buf = Vec::new();
        encode(0, &e1, ValueType::Binary, &mut buf);
        encode(1, &e2, ValueType::Binary, &mut buf);
        let (v1, next) = parse(&buf, 0, ValueType::Binary);
        let (v2, end) = parse(&buf, next, ValueType::Binary);
        assert_eq!(v1.tile_col, 0);
        assert_eq!(v2.tile_col, 1);
        assert_eq!(end, buf.len());
        assert_eq!(decode(&v2, ValueType::Binary).coords, e2.coords);
    }

    #[test]
    #[should_panic]
    fn empty_tile_rejected() {
        let e = TileEntries::default();
        let mut buf = Vec::new();
        encode(0, &e, ValueType::Binary, &mut buf);
    }
}
