//! Streaming CSR → tiled-image conversion (paper §5.4, Table 2).
//!
//! The paper stores graphs as CSR images and converts once to SCSR; the
//! conversion reads the CSR image sequentially, writes the SCSR image
//! sequentially, is bottlenecked by the store, and its one-time cost is
//! amortized over the many multiplications that follow. We reproduce the
//! same pipeline: both images live on the [`crate::io::ShardedStore`], the
//! converter streams row bands, and the report carries the Table 2 columns
//! (wall time, average I/O throughput).
//!
//! On-disk CSR image layout (little-endian):
//!
//! ```text
//! [header: 48 bytes]  magic "SEMC", version u32, nrows u64, ncols u64,
//!                     nnz u64, valtype u8, reserved
//! [indptr:  u64 × (nrows + 1)]
//! [indices: u32 × nnz]
//! [vals:    f32 × nnz]   (only when valtype = F32)
//! ```

use super::tiled::{TiledMeta, HEADER_LEN};
use super::{dcsc, scsr, Csr, TileEntries, TileFormat, ValueType};
use crate::io::{ShardedFile, ShardedStore};
use crate::metrics::Stopwatch;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Magic bytes of a CSR image.
pub const CSR_MAGIC: [u8; 4] = *b"SEMC";
/// CSR image header size.
pub const CSR_HEADER: usize = 48;

/// Serialize a CSR matrix into its on-store image format.
pub fn csr_image_bytes(m: &Csr) -> Vec<u8> {
    let vt = if m.vals.is_some() {
        ValueType::F32
    } else {
        ValueType::Binary
    };
    let mut out = Vec::with_capacity(
        CSR_HEADER + (m.nrows + 1) * 8 + m.nnz() * (4 + vt.bytes()),
    );
    out.extend_from_slice(&CSR_MAGIC);
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(m.nrows as u64).to_le_bytes());
    out.extend_from_slice(&(m.ncols as u64).to_le_bytes());
    out.extend_from_slice(&(m.nnz() as u64).to_le_bytes());
    out.push(vt.code());
    out.resize(CSR_HEADER, 0);
    for &p in &m.indptr {
        out.extend_from_slice(&p.to_le_bytes());
    }
    for &c in &m.indices {
        out.extend_from_slice(&c.to_le_bytes());
    }
    if let Some(vals) = &m.vals {
        for &v in vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Store a CSR matrix as an image object.
pub fn put_csr_image(store: &Arc<ShardedStore>, name: &str, m: &Csr) -> Result<()> {
    store.put(name, &csr_image_bytes(m))
}

/// Parsed CSR image header.
#[derive(Debug, Clone)]
pub struct CsrImageHeader {
    /// Matrix rows.
    pub nrows: usize,
    /// Matrix columns.
    pub ncols: usize,
    /// Non-zeros in the matrix.
    pub nnz: u64,
    /// Value payload per non-zero.
    pub valtype: ValueType,
}

impl CsrImageHeader {
    /// Byte offset of the indptr array within the image.
    pub fn indptr_off(&self) -> u64 {
        CSR_HEADER as u64
    }

    /// Byte offset of the column-index array within the image.
    pub fn indices_off(&self) -> u64 {
        self.indptr_off() + (self.nrows as u64 + 1) * 8
    }

    /// Byte offset of the value array within the image.
    pub fn vals_off(&self) -> u64 {
        self.indices_off() + self.nnz * 4
    }
}

/// Read and validate a CSR image header.
pub fn read_csr_header(f: &ShardedFile) -> Result<CsrImageHeader> {
    let mut h = [0u8; CSR_HEADER];
    f.read_at(0, &mut h)?;
    if h[0..4] != CSR_MAGIC {
        bail!("bad CSR image magic");
    }
    let valtype = match ValueType::from_code(h[32]) {
        Some(v) => v,
        None => bail!("bad CSR image value type"),
    };
    Ok(CsrImageHeader {
        nrows: u64::from_le_bytes(h[8..16].try_into().unwrap()) as usize,
        ncols: u64::from_le_bytes(h[16..24].try_into().unwrap()) as usize,
        nnz: u64::from_le_bytes(h[24..32].try_into().unwrap()),
        valtype,
    })
}

/// Load a full CSR image object back into memory (baseline inputs).
pub fn read_csr_image(store: &Arc<ShardedStore>, name: &str) -> Result<Csr> {
    let f = store.open_file(name)?;
    let hdr = read_csr_header(&f)?;
    let mut indptr = vec![0u64; hdr.nrows + 1];
    let mut buf = vec![0u8; (hdr.nrows + 1) * 8];
    f.read_at(hdr.indptr_off(), &mut buf)?;
    for (i, p) in indptr.iter_mut().enumerate() {
        *p = u64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap());
    }
    let mut idx_buf = vec![0u8; hdr.nnz as usize * 4];
    if hdr.nnz > 0 {
        f.read_at(hdr.indices_off(), &mut idx_buf)?;
    }
    let indices: Vec<u32> = idx_buf
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let vals = if hdr.valtype == ValueType::F32 {
        let mut vbuf = vec![0u8; hdr.nnz as usize * 4];
        if hdr.nnz > 0 {
            f.read_at(hdr.vals_off(), &mut vbuf)?;
        }
        Some(
            vbuf.chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect(),
        )
    } else {
        None
    };
    Ok(Csr {
        nrows: hdr.nrows,
        ncols: hdr.ncols,
        indptr,
        indices,
        vals,
    })
}

/// Conversion report — the Table 2 columns.
#[derive(Debug, Clone)]
pub struct ConversionReport {
    /// Wall-clock seconds of the conversion.
    pub secs: f64,
    /// Bytes read from the CSR image.
    pub bytes_read: u64,
    /// Bytes written to the tiled image.
    pub bytes_written: u64,
    /// Average combined I/O throughput in GB/s over the conversion.
    pub io_gbps: f64,
    /// Size of the produced tile data area.
    pub tiled_bytes: u64,
}

/// Convert a CSR image object into a tiled image object, streaming both
/// through the store (one sequential read pass + one sequential write
/// pass, the minimum I/O — Table 2). Peak memory is O(nrows) for the
/// indptr plus one row band.
pub fn convert(
    store: &Arc<ShardedStore>,
    csr_name: &str,
    out_name: &str,
    tile: usize,
    format: TileFormat,
) -> Result<ConversionReport> {
    let sw = Stopwatch::start();
    let read0 = store.stats.bytes_read.get();
    let written0 = store.stats.bytes_written.get();

    let src = store.open_file(csr_name)?;
    let hdr = read_csr_header(&src)?;
    let vt = hdr.valtype;

    // indptr stays in memory — the O(n) component of the SEM memory bound.
    let mut indptr = vec![0u64; hdr.nrows + 1];
    {
        let mut buf = vec![0u8; (hdr.nrows + 1) * 8];
        src.read_at(hdr.indptr_off(), &mut buf)?;
        for (i, p) in indptr.iter_mut().enumerate() {
            *p = u64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap());
        }
    }

    let meta = TiledMeta {
        nrows: hdr.nrows,
        ncols: hdr.ncols,
        tile,
        format,
        valtype: vt,
        nnz: hdr.nnz,
    };
    let ntr = meta.n_tile_rows();
    let ntc = meta.n_tile_cols();
    let dst = store.create_file(out_name)?;
    let data_start = (HEADER_LEN + ntr * 16) as u64;

    let mut index: Vec<(u64, u64)> = Vec::with_capacity(ntr);
    let mut data_off = 0u64;
    let mut buckets: Vec<TileEntries> = vec![TileEntries::default(); ntc];
    let mut dirty: Vec<usize> = Vec::new();
    let mut band = Vec::new();

    for tr in 0..ntr {
        let row_lo = tr * tile;
        let row_hi = (row_lo + tile).min(hdr.nrows);
        let (k0, k1) = (indptr[row_lo], indptr[row_hi]);
        let n = (k1 - k0) as usize;

        // One sequential read of the band's indices (+ values).
        let mut idx_buf = vec![0u8; n * 4];
        if n > 0 {
            src.read_at(hdr.indices_off() + k0 * 4, &mut idx_buf)?;
        }
        let mut val_buf = Vec::new();
        if vt == ValueType::F32 && n > 0 {
            val_buf = vec![0u8; n * 4];
            src.read_at(hdr.vals_off() + k0 * 4, &mut val_buf)?;
        }

        for r in row_lo..row_hi {
            let lr = (r - row_lo) as u16;
            let (s, e) = (
                (indptr[r] - k0) as usize,
                (indptr[r + 1] - k0) as usize,
            );
            for k in s..e {
                let c =
                    u32::from_le_bytes(idx_buf[k * 4..k * 4 + 4].try_into().unwrap()) as usize;
                let tc = c / tile;
                let b = &mut buckets[tc];
                if b.coords.is_empty() {
                    dirty.push(tc);
                }
                b.coords.push((lr, (c - tc * tile) as u16));
                if vt == ValueType::F32 {
                    b.vals.push(f32::from_le_bytes(
                        val_buf[k * 4..k * 4 + 4].try_into().unwrap(),
                    ));
                }
            }
        }
        dirty.sort_unstable();
        band.clear();
        for &tc in &dirty {
            let b = &mut buckets[tc];
            match format {
                TileFormat::Scsr => {
                    scsr::encode(tc as u32, b, vt, &mut band);
                }
                TileFormat::Dcsc => {
                    dcsc::encode(tc as u32, b, vt, &mut band);
                }
            }
            b.coords.clear();
            b.vals.clear();
        }
        dirty.clear();
        // One sequential write of the encoded tile row.
        if !band.is_empty() {
            dst.write_at(data_start + data_off, &band)?;
        }
        index.push((data_off, band.len() as u64));
        data_off += band.len() as u64;
    }

    // Header + index last (they are small; the data writes stayed
    // sequential).
    let mut head = Vec::with_capacity(data_start as usize);
    {
        // Reuse TiledImage::write_to via a temporary empty-data image.
        let tmp = super::tiled::TiledImage {
            meta,
            index,
            data: Vec::new(),
        };
        tmp.write_to(&mut head)?;
    }
    dst.write_at(0, &head)?;
    dst.sync()?;

    let secs = sw.secs();
    let bytes_read = store.stats.bytes_read.get() - read0;
    let bytes_written = store.stats.bytes_written.get() - written0;
    Ok(ConversionReport {
        secs,
        bytes_read,
        bytes_written,
        io_gbps: (bytes_read + bytes_written) as f64 / 1e9 / secs,
        tiled_bytes: data_off,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::graph::rmat;
    use crate::io::StoreSpec;

    fn sample() -> Csr {
        let el = rmat::generate(11, 14_000, rmat::RmatParams::default(), 8);
        Csr::from_edgelist(&el)
    }

    #[test]
    fn convert_matches_direct_build() {
        let m = sample();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        put_csr_image(&store, "g.csr", &m).unwrap();
        let report = convert(&store, "g.csr", "g.semm", 256, TileFormat::Scsr).unwrap();
        assert!(report.bytes_read > 0 && report.bytes_written > 0);

        let direct = TiledImage::build(&m, 256, TileFormat::Scsr);
        let converted = TiledImage::load(&store.path("g.semm")).unwrap();
        assert_eq!(converted.meta, direct.meta);
        assert_eq!(converted.index, direct.index);
        assert_eq!(converted.data, direct.data);
    }

    #[test]
    fn convert_weighted() {
        let mut m = sample();
        m.vals = Some((0..m.nnz()).map(|i| (i % 13) as f32 + 1.0).collect());
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        put_csr_image(&store, "g.csr", &m).unwrap();
        convert(&store, "g.csr", "g.semm", 128, TileFormat::Scsr).unwrap();
        let img = TiledImage::load(&store.path("g.semm")).unwrap();
        let (coords, vals) = crate::format::tiled::decode_all(&img);
        assert_eq!(coords.len(), m.nnz());
        let expect: Vec<f32> = (0..m.nrows)
            .flat_map(|r| m.row_vals(r).unwrap().iter().copied())
            .collect();
        assert_eq!(vals, expect);
    }

    #[test]
    fn csr_header_roundtrip() {
        let m = sample();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        put_csr_image(&store, "g.csr", &m).unwrap();
        let f = store.open_file("g.csr").unwrap();
        let h = read_csr_header(&f).unwrap();
        assert_eq!(h.nrows, m.nrows);
        assert_eq!(h.nnz as usize, m.nnz());
        assert_eq!(h.valtype, ValueType::Binary);
    }

    #[test]
    fn dcsc_target_also_converts() {
        let m = sample();
        let dir = crate::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        put_csr_image(&store, "g.csr", &m).unwrap();
        convert(&store, "g.csr", "g.dcsc", 256, TileFormat::Dcsc).unwrap();
        let img = TiledImage::load(&store.path("g.dcsc")).unwrap();
        let (coords, _) = crate::format::tiled::decode_all(&img);
        assert_eq!(coords.len(), m.nnz());
    }
}
