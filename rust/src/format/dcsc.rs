//! Doubly-compressed sparse column (DCSC) tile encoding — the baseline
//! format the paper compares SCSR against (Buluç & Gilbert, IPDPS 2008;
//! paper Fig 2 and the Fig 13 `SCSR` ablation run tiles in DCSC).
//!
//! Per the paper's cost model a DCSC tile with `nnc` non-empty columns
//! costs `(2 + 2 + 4)·nnc + (2 + c)·nnz`: per non-empty column a 2-byte
//! column id, a 2-byte AUX entry and a 4-byte pointer into the row-index
//! array, then 2 bytes of row index per non-zero (+ values).
//!
//! On-disk layout of one encoded tile:
//!
//! ```text
//! u32  tile_col
//! u32  nnz
//! u32  nnc                    non-empty columns
//! nnc × { u16 col_id, u16 aux, u32 ptr }   column directory
//! u16 × nnz                   row indices, grouped by column
//! f32 × nnz                   values (omitted for binary matrices)
//! ```

use super::{TileEntries, ValueType};

/// Fixed per-tile header size in bytes.
pub const TILE_HEADER: usize = 12;

/// Bytes of column directory per non-empty column.
pub const PER_COL: usize = 8;

/// Analytic storage size: paper's `(2+2+4)·nnc + (2+c)·nnz` + header.
pub fn analytic_size(nnc: usize, nnz: usize, vt: ValueType) -> usize {
    TILE_HEADER + PER_COL * nnc + (2 + vt.bytes()) * nnz
}

/// Encode one tile. Entries must be sorted by (row, col) as produced by
/// the tiler; we regroup by column internally.
pub fn encode(tile_col: u32, entries: &TileEntries, vt: ValueType, out: &mut Vec<u8>) -> usize {
    let start = out.len();
    let nnz = entries.nnz();
    assert!(nnz > 0, "empty tiles are not stored");

    // Group by column: collect (col, row, val-index) sorted by (col, row).
    let mut by_col: Vec<(u16, u16, usize)> = entries
        .coords
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| (c, r, i))
        .collect();
    by_col.sort_unstable();

    let mut cols: Vec<(u16, u32)> = Vec::new(); // (col_id, start ptr)
    for (k, &(c, _, _)) in by_col.iter().enumerate() {
        if cols.last().map(|&(lc, _)| lc) != Some(c) {
            cols.push((c, k as u32));
        }
    }
    let nnc = cols.len();

    out.extend_from_slice(&tile_col.to_le_bytes());
    out.extend_from_slice(&(nnz as u32).to_le_bytes());
    out.extend_from_slice(&(nnc as u32).to_le_bytes());
    for &(c, ptr) in &cols {
        out.extend_from_slice(&c.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // AUX (unused here)
        out.extend_from_slice(&ptr.to_le_bytes());
    }
    for &(_, r, _) in &by_col {
        out.extend_from_slice(&r.to_le_bytes());
    }
    if vt == ValueType::F32 {
        for &(_, _, i) in &by_col {
            out.extend_from_slice(&entries.vals[i].to_le_bytes());
        }
    }
    out.len() - start
}

/// A zero-copy view over one encoded DCSC tile.
#[derive(Debug, Clone, Copy)]
pub struct TileView<'a> {
    /// Column-block index of this tile inside its tile row.
    pub tile_col: u32,
    /// Non-zeros in the tile.
    pub nnz: usize,
    /// Non-empty columns.
    pub nnc: usize,
    /// Column directory bytes (`8 * nnc`).
    pub coldir: &'a [u8],
    /// Row-index bytes (`2 * nnz`).
    pub rows: &'a [u8],
    /// Value bytes (`4 * nnz`, empty for binary).
    pub vals: &'a [u8],
}

impl<'a> TileView<'a> {
    /// Column id and row-range of directory entry `k`.
    #[inline]
    pub fn col(&self, k: usize) -> (u16, usize, usize) {
        let base = k * PER_COL;
        let cid = u16::from_le_bytes([self.coldir[base], self.coldir[base + 1]]);
        let ptr =
            u32::from_le_bytes(self.coldir[base + 4..base + 8].try_into().unwrap()) as usize;
        let end = if k + 1 < self.nnc {
            u32::from_le_bytes(
                self.coldir[base + PER_COL + 4..base + PER_COL + 8]
                    .try_into()
                    .unwrap(),
            ) as usize
        } else {
            self.nnz
        };
        (cid, ptr, end)
    }

    /// Row index of entry `i`.
    #[inline]
    pub fn row(&self, i: usize) -> u16 {
        u16::from_le_bytes([self.rows[2 * i], self.rows[2 * i + 1]])
    }

    /// Value of entry `i` (binary tiles return 1.0).
    #[inline]
    pub fn val(&self, i: usize) -> f32 {
        if self.vals.is_empty() {
            1.0
        } else {
            f32::from_le_bytes(self.vals[4 * i..4 * i + 4].try_into().unwrap())
        }
    }
}

/// Parse one tile at `buf[off..]`; returns the view and the next offset.
pub fn parse(buf: &[u8], off: usize, vt: ValueType) -> (TileView<'_>, usize) {
    let tile_col = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
    let nnz = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap()) as usize;
    let nnc = u32::from_le_bytes(buf[off + 8..off + 12].try_into().unwrap()) as usize;
    let dir_start = off + TILE_HEADER;
    let rows_start = dir_start + nnc * PER_COL;
    let vals_start = rows_start + nnz * 2;
    let end = vals_start + nnz * vt.bytes();
    (
        TileView {
            tile_col,
            nnz,
            nnc,
            coldir: &buf[dir_start..rows_start],
            rows: &buf[rows_start..vals_start],
            vals: &buf[vals_start..end],
        },
        end,
    )
}

/// Decode back to sorted [`TileEntries`] (tests / verification).
pub fn decode(view: &TileView<'_>, vt: ValueType) -> TileEntries {
    let mut tmp: Vec<((u16, u16), f32)> = Vec::with_capacity(view.nnz);
    for k in 0..view.nnc {
        let (c, s, e) = view.col(k);
        for i in s..e {
            tmp.push(((view.row(i), c), view.val(i)));
        }
    }
    tmp.sort_unstable_by_key(|&(rc, _)| rc);
    let mut out = TileEntries::default();
    for (rc, v) in tmp {
        out.coords.push(rc);
        if vt == ValueType::F32 {
            out.vals.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_tile(t: u16, n: usize, seed: u64, weighted: bool) -> TileEntries {
        let mut rng = Xoshiro256::new(seed);
        let mut coords: Vec<(u16, u16)> = (0..n)
            .map(|_| (rng.below(t as u64) as u16, rng.below(t as u64) as u16))
            .collect();
        coords.sort_unstable();
        coords.dedup();
        let vals = if weighted {
            coords.iter().map(|_| rng.next_f32() + 0.1).collect()
        } else {
            Vec::new()
        };
        TileEntries { coords, vals }
    }

    #[test]
    fn roundtrip_binary() {
        let e = random_tile(1024, 4000, 1, false);
        let mut buf = Vec::new();
        encode(9, &e, ValueType::Binary, &mut buf);
        let (v, end) = parse(&buf, 0, ValueType::Binary);
        assert_eq!(end, buf.len());
        assert_eq!(v.tile_col, 9);
        assert_eq!(decode(&v, ValueType::Binary).coords, e.coords);
    }

    #[test]
    fn roundtrip_weighted() {
        let e = random_tile(300, 900, 2, true);
        let mut buf = Vec::new();
        encode(1, &e, ValueType::F32, &mut buf);
        let (v, _) = parse(&buf, 0, ValueType::F32);
        let d = decode(&v, ValueType::F32);
        assert_eq!(d.coords, e.coords);
        assert_eq!(d.vals, e.vals);
    }

    #[test]
    fn size_matches_analytic() {
        let e = random_tile(2048, 3000, 3, false);
        let nnc = {
            let mut cols: Vec<u16> = e.coords.iter().map(|&(_, c)| c).collect();
            cols.sort_unstable();
            cols.dedup();
            cols.len()
        };
        let mut buf = Vec::new();
        let sz = encode(0, &e, ValueType::Binary, &mut buf);
        assert_eq!(sz, analytic_size(nnc, e.nnz(), ValueType::Binary));
    }

    #[test]
    fn scsr_smaller_than_dcsc_on_sparse_tiles() {
        // The paper's headline format claim (Fig 2): for sparse power-law
        // tiles SCSR ≈ 45-70% of DCSC. A uniformly sparse tile where most
        // rows/cols have ~1 entry shows the effect strongly.
        let e = random_tile(8192, 6000, 4, false);
        let mut a = Vec::new();
        let mut b = Vec::new();
        let s = super::super::scsr::encode(0, &e, ValueType::Binary, &mut a);
        let d = encode(0, &e, ValueType::Binary, &mut b);
        assert!(
            (s as f64) < 0.8 * d as f64,
            "SCSR {s} should be well below DCSC {d}"
        );
    }
}
