//! Delta-run encoding and the canonical base ⊕ delta tile-row merge.
//!
//! A *delta run* ("SEMD") is the on-store unit of the LSM update layer:
//! a batch of edge edits — inserts, weight updates, and tombstoned
//! deletes — sorted by `(row, col)` and grouped into the same tile-row
//! bands as the base image, so a streaming sweep can pair run slices
//! with base tile rows without any seeking. Runs are tiny next to the
//! base (13 bytes per edit) and are folded away by compaction
//! ([`crate::io::delta`]).
//!
//! Run layout (little-endian), mirroring the SEMM image shape:
//!
//! ```text
//! [header: 64 bytes]
//!   magic "SEMD", version u32, nrows u64, ncols u64, tile u32,
//!   format u8, valtype u8, pad u16, seq u64, n_ops u64, n_tile_rows u32
//! [index: n_tile_rows × (offset u64, len u64)]   offsets into data area
//! [data:  13-byte records (row u32, col u32, flags u8, val f32),
//!         sorted by (row, col), grouped per tile row]
//! ```
//!
//! The correctness heart of the layer is [`merge_tile_row`]: it rewrites
//! one base tile row with a sorted slice of collapsed edits into
//! **exactly** the bytes [`super::tiled::TiledImage::build`] would have
//! produced for the mutated matrix — non-empty tiles in ascending
//! tile-column order, coordinates `(local row, local col)`-sorted, same
//! SCSR/DCSC encoder, same value type. Byte-level canonicality is what
//! lets the differential suite demand *bit-identical* sweep outputs
//! against a from-scratch reconversion in every semiring, and what makes
//! major compaction's output a first-class image.

use super::tiled::TiledMeta;
use super::{dcsc, scsr, TileEntries, TileFormat, ValueType};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Magic bytes of a delta run.
pub const RUN_MAGIC: [u8; 4] = *b"SEMD";
/// Run format version.
pub const RUN_VERSION: u32 = 1;
/// Fixed run header size (same as the image header).
pub const RUN_HEADER_LEN: usize = 64;
/// Bytes per edit record: row u32 + col u32 + flags u8 + val f32.
pub const OP_BYTES: usize = 13;

/// One edge edit. An upsert (`tombstone = false`) inserts the edge or
/// replaces its value; a tombstone deletes it (and is a no-op if the
/// edge does not exist). For binary images the value is ignored — an
/// upsert is pure pattern.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeltaOp {
    /// Destination vertex (matrix row; images store `A[dst][src]`).
    pub row: u32,
    /// Source vertex (matrix column).
    pub col: u32,
    /// `true` = delete this edge.
    pub tombstone: bool,
    /// Edge weight for upserts into F32 images.
    pub val: f32,
}

impl DeltaOp {
    /// An insert / weight-update record.
    pub fn upsert(row: u32, col: u32, val: f32) -> DeltaOp {
        DeltaOp {
            row,
            col,
            tombstone: false,
            val,
        }
    }

    /// A delete record.
    pub fn delete(row: u32, col: u32) -> DeltaOp {
        DeltaOp {
            row,
            col,
            tombstone: true,
            val: 0.0,
        }
    }

    fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.row.to_le_bytes());
        out.extend_from_slice(&self.col.to_le_bytes());
        out.push(self.tombstone as u8);
        out.extend_from_slice(&self.val.to_le_bytes());
    }

    fn read(b: &[u8]) -> DeltaOp {
        DeltaOp {
            row: u32::from_le_bytes(b[0..4].try_into().unwrap()),
            col: u32::from_le_bytes(b[4..8].try_into().unwrap()),
            tombstone: b[8] != 0,
            val: f32::from_le_bytes(b[9..13].try_into().unwrap()),
        }
    }
}

/// Parsed run header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunMeta {
    /// Shape/encoding of the base image this run applies to.
    pub image: TiledMeta,
    /// Commit sequence number (monotone per dataset).
    pub seq: u64,
    /// Edit records in the run.
    pub n_ops: u64,
}

/// Encode a sorted, coordinate-unique batch of edits as one run.
pub fn encode_run(meta: &TiledMeta, seq: u64, ops: &[DeltaOp]) -> Vec<u8> {
    debug_assert!(ops
        .windows(2)
        .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col)));
    let ntr = meta.n_tile_rows();
    let mut out = Vec::with_capacity(RUN_HEADER_LEN + ntr * 16 + ops.len() * OP_BYTES);
    out.extend_from_slice(&RUN_MAGIC);
    out.extend_from_slice(&RUN_VERSION.to_le_bytes());
    out.extend_from_slice(&(meta.nrows as u64).to_le_bytes());
    out.extend_from_slice(&(meta.ncols as u64).to_le_bytes());
    out.extend_from_slice(&(meta.tile as u32).to_le_bytes());
    out.push(meta.format.code());
    out.push(meta.valtype.code());
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(ops.len() as u64).to_le_bytes());
    out.extend_from_slice(&(ntr as u32).to_le_bytes());
    out.resize(RUN_HEADER_LEN, 0);

    // Per-tile-row index: ops are (row, col)-sorted, so each band is a
    // contiguous record range.
    let mut index = Vec::with_capacity(ntr);
    let mut k = 0usize;
    for tr in 0..ntr {
        let hi = ((tr + 1) * meta.tile) as u32;
        let start = k;
        while k < ops.len() && ops[k].row < hi {
            k += 1;
        }
        index.push(((start * OP_BYTES) as u64, ((k - start) * OP_BYTES) as u64));
    }
    for &(off, len) in &index {
        out.extend_from_slice(&off.to_le_bytes());
        out.extend_from_slice(&len.to_le_bytes());
    }
    for op in ops {
        op.write(&mut out);
    }
    out
}

/// Decode a run back into its header and sorted edit list.
pub fn decode_run(bytes: &[u8]) -> Result<(RunMeta, Vec<DeltaOp>)> {
    if bytes.len() < RUN_HEADER_LEN || bytes[0..4] != RUN_MAGIC {
        bail!("bad delta-run magic");
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != RUN_VERSION {
        bail!("unsupported delta-run version {version}");
    }
    let image = TiledMeta {
        nrows: u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize,
        ncols: u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize,
        tile: u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize,
        format: TileFormat::from_code(bytes[28])?,
        valtype: ValueType::from_code(bytes[29])?,
        nnz: 0,
    };
    let seq = u64::from_le_bytes(bytes[32..40].try_into().unwrap());
    let n_ops = u64::from_le_bytes(bytes[40..48].try_into().unwrap());
    let ntr = u32::from_le_bytes(bytes[48..52].try_into().unwrap()) as usize;
    if image.tile == 0 {
        bail!("delta-run header has tile size 0");
    }
    if ntr != image.n_tile_rows() {
        bail!("inconsistent delta-run tile-row count");
    }
    let data_start = RUN_HEADER_LEN + ntr * 16;
    let need = data_start + n_ops as usize * OP_BYTES;
    if bytes.len() < need {
        bail!("truncated delta run: {} < {need} bytes", bytes.len());
    }
    let mut ops = Vec::with_capacity(n_ops as usize);
    for k in 0..n_ops as usize {
        let at = data_start + k * OP_BYTES;
        let op = DeltaOp::read(&bytes[at..at + OP_BYTES]);
        // Corruption that keeps a plausible header (e.g. a truncated
        // data area padded back out) must fail here, not panic later in
        // overlay bucketing or the tile-row merge.
        if op.row as usize >= image.nrows || op.col as usize >= image.ncols {
            bail!(
                "delta run (seq {seq}) op {k} at ({}, {}) outside the {}×{} image",
                op.row,
                op.col,
                image.nrows,
                image.ncols
            );
        }
        ops.push(op);
    }
    Ok((RunMeta { image, seq, n_ops }, ops))
}

/// Fold runs (oldest first) into one coordinate-unique edit list,
/// newest edit winning per coordinate, sorted by `(row, col)`.
/// Tombstones survive the fold — they still have base entries to mask.
pub fn collapse<'a>(runs: impl IntoIterator<Item = &'a [DeltaOp]>) -> Vec<DeltaOp> {
    let mut m: BTreeMap<(u32, u32), DeltaOp> = BTreeMap::new();
    for run in runs {
        for op in run {
            m.insert((op.row, op.col), *op);
        }
    }
    m.into_values().collect()
}

/// The in-memory overlay a [`crate::spmm::DeltaSource`] applies during a
/// sweep: the collapsed edits bucketed per tile row (each bucket
/// `(row, col)`-sorted and coordinate-unique).
#[derive(Debug, Default)]
pub struct DeltaOverlay {
    /// Collapsed edits of tile row `tr` at `ops_by_tr[tr]`.
    pub ops_by_tr: Vec<Vec<DeltaOp>>,
    /// Total edits across all tile rows.
    pub n_ops: usize,
}

impl DeltaOverlay {
    /// Bucket a collapsed, sorted edit list by tile row.
    pub fn new(meta: &TiledMeta, ops: Vec<DeltaOp>) -> DeltaOverlay {
        let mut ops_by_tr = vec![Vec::new(); meta.n_tile_rows()];
        let n_ops = ops.len();
        for op in ops {
            ops_by_tr[op.row as usize / meta.tile].push(op);
        }
        DeltaOverlay { ops_by_tr, n_ops }
    }

    /// Whether any edit lands in tile rows `[lo, hi)`.
    pub fn touches(&self, lo: usize, hi: usize) -> bool {
        self.ops_by_tr[lo..hi].iter().any(|v| !v.is_empty())
    }

    /// Whether the overlay holds no edits at all.
    pub fn is_empty(&self) -> bool {
        self.n_ops == 0
    }
}

fn decode_tile(bytes: &[u8], off: usize, meta: &TiledMeta) -> (u32, TileEntries, usize) {
    match meta.format {
        TileFormat::Scsr => {
            let (view, next) = scsr::parse(bytes, off, meta.valtype);
            (view.tile_col, scsr::decode(&view, meta.valtype), next)
        }
        TileFormat::Dcsc => {
            let (view, next) = dcsc::parse(bytes, off, meta.valtype);
            (view.tile_col, dcsc::decode(&view, meta.valtype), next)
        }
    }
}

/// Two-pointer merge of one tile's sorted base entries with its sorted
/// edits. Upserts replace or insert; tombstones drop (a tombstone for an
/// absent entry is a no-op). Values are kept only for F32 images.
fn merge_entries(base: &TileEntries, ops: &[(u16, u16, bool, f32)], vt: ValueType) -> TileEntries {
    let keep_vals = vt == ValueType::F32;
    let mut out = TileEntries::default();
    let (mut i, mut j) = (0usize, 0usize);
    while i < base.coords.len() || j < ops.len() {
        let take_base = match (base.coords.get(i), ops.get(j)) {
            (Some(&bc), Some(&(or, oc, _, _))) => bc < (or, oc),
            (Some(_), None) => true,
            _ => false,
        };
        if take_base {
            out.coords.push(base.coords[i]);
            if keep_vals {
                out.vals.push(base.vals[i]);
            }
            i += 1;
        } else {
            let (or, oc, tomb, val) = ops[j];
            let hit = base.coords.get(i) == Some(&(or, oc));
            if !tomb {
                out.coords.push((or, oc));
                if keep_vals {
                    out.vals.push(val);
                }
            }
            if hit {
                i += 1;
            }
            j += 1;
        }
    }
    out
}

/// Rewrite one base tile row under a sorted, coordinate-unique edit
/// slice, appending the merged tile row to `out` in the image's
/// canonical form: the exact bytes [`super::tiled::TiledImage::build`]
/// emits for the mutated matrix. Returns the merged entry count (the
/// tile row's contribution to the new `nnz`).
pub fn merge_tile_row(
    meta: &TiledMeta,
    tr: usize,
    base: &[u8],
    ops: &[DeltaOp],
    out: &mut Vec<u8>,
) -> usize {
    let t = meta.tile;
    let row_lo = tr * t;
    // Bucket edits by tile column, coordinates localized. Buckets keep
    // the (row, col) order, which localizes to (local row, local col).
    let mut buckets: BTreeMap<u32, Vec<(u16, u16, bool, f32)>> = BTreeMap::new();
    for op in ops {
        debug_assert_eq!(op.row as usize / t, tr, "edit outside its tile row");
        let tc = op.col as usize / t;
        buckets.entry(tc as u32).or_default().push((
            (op.row as usize - row_lo) as u16,
            (op.col as usize - tc * t) as u16,
            op.tombstone,
            op.val,
        ));
    }

    let empty = TileEntries::default();
    let mut nnz = 0usize;
    let mut off = 0usize;
    let mut pending = buckets.into_iter().peekable();
    let mut emit = |tc: u32, e: &TileEntries, out: &mut Vec<u8>| {
        nnz += e.nnz();
        if e.nnz() == 0 {
            return;
        }
        match meta.format {
            TileFormat::Scsr => {
                scsr::encode(tc, e, meta.valtype, out);
            }
            TileFormat::Dcsc => {
                dcsc::encode(tc, e, meta.valtype, out);
            }
        }
    };
    while off < base.len() {
        let (tc, entries, next) = decode_tile(base, off, meta);
        off = next;
        // Edit-only tiles left of this base tile come first.
        while pending.peek().is_some_and(|&(ptc, _)| ptc < tc) {
            let (ptc, pops) = pending.next().unwrap();
            emit(ptc, &merge_entries(&empty, &pops, meta.valtype), out);
        }
        if pending.peek().is_some_and(|&(ptc, _)| ptc == tc) {
            let (_, pops) = pending.next().unwrap();
            emit(tc, &merge_entries(&entries, &pops, meta.valtype), out);
        } else {
            emit(tc, &entries, out);
        }
    }
    for (ptc, pops) in pending {
        emit(ptc, &merge_entries(&empty, &pops, meta.valtype), out);
    }
    nnz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::tiled::TiledImage;
    use crate::format::Csr;
    use crate::util::Xoshiro256;

    fn sample_csr(weighted: bool, seed: u64) -> Csr {
        let mut rng = Xoshiro256::new(seed);
        let n = 300usize;
        let mut pairs: Vec<(u32, u32)> = (0..2000)
            .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        let mut m = Csr::from_sorted_pairs(n, n, &pairs);
        if weighted {
            m.vals = Some(pairs.iter().map(|_| rng.next_f32() + 0.5).collect());
        }
        m
    }

    fn mutate(m: &Csr, ops: &[DeltaOp]) -> Csr {
        let mut map: BTreeMap<(u32, u32), f32> = BTreeMap::new();
        let weighted = m.vals.is_some();
        for r in 0..m.nrows {
            for k in m.indptr[r] as usize..m.indptr[r + 1] as usize {
                let v = m.vals.as_ref().map_or(1.0, |v| v[k]);
                map.insert((r as u32, m.indices[k]), v);
            }
        }
        for op in ops {
            if op.tombstone {
                map.remove(&(op.row, op.col));
            } else {
                map.insert((op.row, op.col), op.val);
            }
        }
        let pairs: Vec<(u32, u32)> = map.keys().copied().collect();
        let mut out = Csr::from_sorted_pairs(m.nrows, m.ncols, &pairs);
        if weighted {
            out.vals = Some(map.values().copied().collect());
        }
        out
    }

    fn sample_ops(m: &Csr, seed: u64, n: usize) -> Vec<DeltaOp> {
        let mut rng = Xoshiro256::new(seed);
        let mut raw: Vec<DeltaOp> = Vec::new();
        for _ in 0..n {
            let row = rng.below(m.nrows as u64) as u32;
            let col = rng.below(m.ncols as u64) as u32;
            if rng.below(3) == 0 {
                raw.push(DeltaOp::delete(row, col));
            } else {
                raw.push(DeltaOp::upsert(row, col, rng.next_f32() + 0.25));
            }
        }
        collapse([raw.as_slice()])
    }

    #[test]
    fn run_roundtrip() {
        let m = sample_csr(true, 1);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        let ops = sample_ops(&m, 2, 500);
        let bytes = encode_run(&img.meta, 7, &ops);
        let (rm, got) = decode_run(&bytes).unwrap();
        assert_eq!(rm.seq, 7);
        assert_eq!(rm.n_ops as usize, ops.len());
        assert_eq!(rm.image.tile, 64);
        assert_eq!(got, ops);
        // The per-tile-row index tiles the data area exactly.
        let ntr = img.meta.n_tile_rows();
        let mut expect = 0u64;
        for tr in 0..ntr {
            let at = RUN_HEADER_LEN + tr * 16;
            let off = u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap());
            let len = u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap());
            assert_eq!(off, expect, "tile row {tr}");
            expect += len;
        }
        assert_eq!(expect, (ops.len() * OP_BYTES) as u64);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_run(b"nope").is_err());
        let m = sample_csr(false, 3);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        let mut bytes = encode_run(&img.meta, 0, &sample_ops(&m, 4, 100));
        bytes.truncate(bytes.len() - 5);
        assert!(decode_run(&bytes).is_err());
    }

    #[test]
    fn decode_rejects_out_of_range_ops() {
        let m = sample_csr(false, 5);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        let mut ops = sample_ops(&m, 6, 50);
        ops.push(DeltaOp::upsert(img.meta.nrows as u32, 0, 1.0));
        assert!(decode_run(&encode_run(&img.meta, 0, &ops)).is_err());
        let bad_col = encode_run(
            &img.meta,
            0,
            &[DeltaOp::upsert(0, img.meta.ncols as u32, 1.0)],
        );
        assert!(decode_run(&bad_col).is_err());
    }

    #[test]
    fn merge_is_canonical_per_tile_row() {
        for (weighted, fmt) in [
            (false, TileFormat::Scsr),
            (true, TileFormat::Scsr),
            (false, TileFormat::Dcsc),
            (true, TileFormat::Dcsc),
        ] {
            let m = sample_csr(weighted, 11);
            let img = TiledImage::build(&m, 64, fmt);
            let ops = sample_ops(&m, 12, 600);
            let want = TiledImage::build(&mutate(&m, &ops), 64, fmt);
            let overlay = DeltaOverlay::new(&img.meta, ops);
            let mut nnz = 0usize;
            for tr in 0..img.meta.n_tile_rows() {
                let mut merged = Vec::new();
                nnz += merge_tile_row(
                    &img.meta,
                    tr,
                    img.tile_row(tr),
                    &overlay.ops_by_tr[tr],
                    &mut merged,
                );
                assert_eq!(
                    merged,
                    want.tile_row(tr),
                    "tile row {tr} weighted={weighted} {fmt:?}"
                );
            }
            assert_eq!(nnz as u64, want.meta.nnz, "weighted={weighted} {fmt:?}");
        }
    }

    #[test]
    fn tombstone_for_absent_edge_is_a_noop_and_all_deleted_empties_the_row() {
        let m = sample_csr(false, 21);
        let img = TiledImage::build(&m, 64, TileFormat::Scsr);
        // Delete every edge of tile row 0 plus some absent coordinates.
        let mut ops: Vec<DeltaOp> = Vec::new();
        for r in 0..64usize.min(m.nrows) {
            for k in m.indptr[r] as usize..m.indptr[r + 1] as usize {
                ops.push(DeltaOp::delete(r as u32, m.indices[k]));
            }
            ops.push(DeltaOp::delete(r as u32, (m.ncols - 1) as u32));
        }
        let ops = collapse([ops.as_slice()]);
        let mut merged = Vec::new();
        let nnz = merge_tile_row(&img.meta, 0, img.tile_row(0), &ops, &mut merged);
        assert_eq!(nnz, 0);
        assert!(merged.is_empty(), "a fully deleted tile row encodes empty");
    }

    #[test]
    fn collapse_is_newest_wins() {
        let older = [
            DeltaOp::upsert(1, 2, 1.0),
            DeltaOp::upsert(3, 4, 1.0),
            DeltaOp::delete(5, 6),
        ];
        let newer = [DeltaOp::delete(1, 2), DeltaOp::upsert(5, 6, 9.0)];
        let got = collapse([older.as_slice(), newer.as_slice()]);
        assert_eq!(
            got,
            vec![
                DeltaOp::delete(1, 2),
                DeltaOp::upsert(3, 4, 1.0),
                DeltaOp::upsert(5, 6, 9.0),
            ]
        );
    }
}
