//! The tiled sparse-matrix image: a matrix cut into `t × t` cache tiles,
//! tiles grouped into **tile rows** (a band of `t` matrix rows), tile rows
//! stored back to back with an index so the SEM engine can stream any
//! contiguous range of tile rows with one sequential read (§3.2, Fig 1).
//!
//! Image layout (little-endian):
//!
//! ```text
//! [header: 64 bytes]
//!   magic "SEMM", version u32, nrows u64, ncols u64, tile u32,
//!   format u8 (SCSR/DCSC), valtype u8 (binary/f32), pad u16,
//!   nnz u64, n_tile_rows u32, reserved
//! [index: n_tile_rows × (offset u64, len u64)]   offsets into data area
//! [data:  encoded tile rows, each a sequence of non-empty tiles]
//! ```
//!
//! The same bytes serve both execution modes: in-memory SpMM keeps `data`
//! in RAM; semi-external SpMM leaves it on the store and streams tile rows.

use super::{dcsc, scsr, Csr, TileEntries, TileFormat, ValueType};
use crate::util::div_ceil;
use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

/// Magic bytes of an image file.
pub const MAGIC: [u8; 4] = *b"SEMM";
/// Image format version.
pub const VERSION: u32 = 1;
/// Fixed header size.
pub const HEADER_LEN: usize = 64;

/// Image metadata (everything except the tile data itself).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TiledMeta {
    /// Matrix rows.
    pub nrows: usize,
    /// Matrix columns.
    pub ncols: usize,
    /// Tile side length `t`.
    pub tile: usize,
    /// Tile encoding (SCSR or DCSC).
    pub format: TileFormat,
    /// Value payload per non-zero.
    pub valtype: ValueType,
    /// Non-zeros in the matrix.
    pub nnz: u64,
}

impl TiledMeta {
    /// Number of tile rows (bands of `tile` matrix rows).
    pub fn n_tile_rows(&self) -> usize {
        div_ceil(self.nrows, self.tile)
    }

    /// Number of tile columns.
    pub fn n_tile_cols(&self) -> usize {
        div_ceil(self.ncols, self.tile)
    }

    /// Serialize the header to its fixed 64-byte form.
    pub fn to_bytes(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..4].copy_from_slice(&MAGIC);
        h[4..8].copy_from_slice(&VERSION.to_le_bytes());
        h[8..16].copy_from_slice(&(self.nrows as u64).to_le_bytes());
        h[16..24].copy_from_slice(&(self.ncols as u64).to_le_bytes());
        h[24..28].copy_from_slice(&(self.tile as u32).to_le_bytes());
        h[28] = self.format.code();
        h[29] = self.valtype.code();
        h[32..40].copy_from_slice(&self.nnz.to_le_bytes());
        h[40..44].copy_from_slice(&(self.n_tile_rows() as u32).to_le_bytes());
        h
    }

    /// Parse a header from its fixed 64-byte form.
    pub fn from_bytes(h: &[u8]) -> Result<TiledMeta> {
        if h.len() < HEADER_LEN || h[0..4] != MAGIC {
            bail!("bad image magic");
        }
        let version = u32::from_le_bytes(h[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported image version {version}");
        }
        let meta = TiledMeta {
            nrows: u64::from_le_bytes(h[8..16].try_into().unwrap()) as usize,
            ncols: u64::from_le_bytes(h[16..24].try_into().unwrap()) as usize,
            tile: u32::from_le_bytes(h[24..28].try_into().unwrap()) as usize,
            format: TileFormat::from_code(h[28]).context("bad tile format code")?,
            valtype: ValueType::from_code(h[29]).context("bad value type code")?,
            nnz: u64::from_le_bytes(h[32..40].try_into().unwrap()),
        };
        let ntr = u32::from_le_bytes(h[40..44].try_into().unwrap()) as usize;
        if ntr != meta.n_tile_rows() {
            bail!("inconsistent tile-row count");
        }
        Ok(meta)
    }
}

/// A fully in-memory tiled image.
#[derive(Debug, Clone)]
pub struct TiledImage {
    /// Image metadata.
    pub meta: TiledMeta,
    /// Per tile row: (offset into `data`, byte length).
    pub index: Vec<(u64, u64)>,
    /// The encoded tile rows, back to back.
    pub data: Vec<u8>,
}

impl TiledImage {
    /// Build an image from CSR. `tile` must be `<= MAX_TILE` and a power of
    /// two is recommended (the engine's row intervals assume it divides
    /// evenly into NUMA row intervals).
    pub fn build(m: &Csr, tile: usize, format: TileFormat) -> TiledImage {
        assert!(tile >= 1 && tile <= crate::MAX_TILE);
        let vt = if m.vals.is_some() {
            ValueType::F32
        } else {
            ValueType::Binary
        };
        let meta = TiledMeta {
            nrows: m.nrows,
            ncols: m.ncols,
            tile,
            format,
            valtype: vt,
            nnz: m.nnz() as u64,
        };
        let ntr = meta.n_tile_rows();
        let ntc = meta.n_tile_cols();
        let mut index = Vec::with_capacity(ntr);
        let mut data = Vec::new();

        // Per-band tile buckets, reused across bands.
        let mut buckets: Vec<TileEntries> = vec![TileEntries::default(); ntc];
        let mut dirty: Vec<usize> = Vec::new();
        for tr in 0..ntr {
            let row_lo = tr * tile;
            let row_hi = (row_lo + tile).min(m.nrows);
            for r in row_lo..row_hi {
                let lr = (r - row_lo) as u16;
                let (s, e) = (m.indptr[r] as usize, m.indptr[r + 1] as usize);
                for k in s..e {
                    let c = m.indices[k] as usize;
                    let tc = c / tile;
                    let b = &mut buckets[tc];
                    if b.coords.is_empty() {
                        dirty.push(tc);
                    }
                    b.coords.push((lr, (c - tc * tile) as u16));
                    if let Some(vals) = &m.vals {
                        b.vals.push(vals[k]);
                    }
                }
            }
            dirty.sort_unstable();
            let start = data.len() as u64;
            for &tc in &dirty {
                let b = &mut buckets[tc];
                // Rows were visited in order and columns are sorted within
                // a CSR row, so coords are already (row, col)-sorted.
                match format {
                    TileFormat::Scsr => {
                        scsr::encode(tc as u32, b, vt, &mut data);
                    }
                    TileFormat::Dcsc => {
                        dcsc::encode(tc as u32, b, vt, &mut data);
                    }
                }
                b.coords.clear();
                b.vals.clear();
            }
            dirty.clear();
            index.push((start, data.len() as u64 - start));
        }
        TiledImage { meta, index, data }
    }

    /// Bytes of tile row `tr`.
    pub fn tile_row(&self, tr: usize) -> &[u8] {
        let (off, len) = self.index[tr];
        &self.data[off as usize..(off + len) as usize]
    }

    /// Bytes of the contiguous range of tile rows `[lo, hi)`.
    pub fn tile_rows(&self, lo: usize, hi: usize) -> &[u8] {
        let start = self.index[lo].0 as usize;
        let end = (self.index[hi - 1].0 + self.index[hi - 1].1) as usize;
        &self.data[start..end]
    }

    /// Total size of the tile data (the quantity Fig 2 compares).
    pub fn data_bytes(&self) -> u64 {
        self.data.len() as u64
    }

    /// Full serialized image size (header + index + data).
    pub fn image_bytes(&self) -> u64 {
        (HEADER_LEN + self.index.len() * 16 + self.data.len()) as u64
    }

    /// Serialize to a writer.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.meta.to_bytes())?;
        for &(off, len) in &self.index {
            w.write_all(&off.to_le_bytes())?;
            w.write_all(&len.to_le_bytes())?;
        }
        w.write_all(&self.data)?;
        Ok(())
    }

    /// Serialize to a file path.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)?;
        f.flush()?;
        Ok(())
    }

    /// Load an image fully into memory.
    pub fn load(path: &Path) -> Result<TiledImage> {
        let mut f = std::fs::File::open(path)?;
        let (meta, index, data_start) = read_header(&mut f)?;
        let mut data = Vec::new();
        f.seek(SeekFrom::Start(data_start))?;
        f.read_to_end(&mut data)?;
        Ok(TiledImage { meta, index, data })
    }

    /// Parse an image from its serialized bytes (e.g. assembled from a
    /// sharded store, where no single backing file exists).
    pub fn from_bytes(bytes: &[u8]) -> Result<TiledImage> {
        let meta = TiledMeta::from_bytes(bytes)?;
        let ntr = meta.n_tile_rows();
        let data_start = HEADER_LEN + ntr * 16;
        if bytes.len() < data_start {
            bail!("image truncated inside the index");
        }
        let index: Vec<(u64, u64)> = (0..ntr)
            .map(|i| {
                let o = HEADER_LEN + i * 16;
                (
                    u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap()),
                    u64::from_le_bytes(bytes[o + 8..o + 16].try_into().unwrap()),
                )
            })
            .collect();
        Ok(TiledImage {
            meta,
            index,
            data: bytes[data_start..].to_vec(),
        })
    }
}

/// Read header + index from an image file; returns `(meta, index,
/// data_start_offset)`. The SEM engine uses this to stream tile rows
/// without loading the data area.
pub fn read_header(f: &mut std::fs::File) -> Result<(TiledMeta, Vec<(u64, u64)>, u64)> {
    let mut h = [0u8; HEADER_LEN];
    f.seek(SeekFrom::Start(0))?;
    f.read_exact(&mut h)?;
    let meta = TiledMeta::from_bytes(&h)?;
    let ntr = meta.n_tile_rows();
    let mut idx_bytes = vec![0u8; ntr * 16];
    f.read_exact(&mut idx_bytes)?;
    let index: Vec<(u64, u64)> = (0..ntr)
        .map(|i| {
            (
                u64::from_le_bytes(idx_bytes[i * 16..i * 16 + 8].try_into().unwrap()),
                u64::from_le_bytes(idx_bytes[i * 16 + 8..i * 16 + 16].try_into().unwrap()),
            )
        })
        .collect();
    Ok((meta, index, (HEADER_LEN + ntr * 16) as u64))
}

/// Decode an entire image back to sorted global (row, col, val) triples —
/// the verification path used by tests and `convert` checks.
pub fn decode_all(img: &TiledImage) -> (Vec<(u32, u32)>, Vec<f32>) {
    let mut coords = Vec::with_capacity(img.meta.nnz as usize);
    let mut vals = Vec::new();
    let t = img.meta.tile;
    for tr in 0..img.meta.n_tile_rows() {
        let buf = img.tile_row(tr);
        let mut off = 0usize;
        while off < buf.len() {
            match img.meta.format {
                TileFormat::Scsr => {
                    let (view, next) = scsr::parse(buf, off, img.meta.valtype);
                    let e = scsr::decode(&view, img.meta.valtype);
                    for (i, &(lr, lc)) in e.coords.iter().enumerate() {
                        coords.push((
                            (tr * t + lr as usize) as u32,
                            (view.tile_col as usize * t + lc as usize) as u32,
                        ));
                        if img.meta.valtype == ValueType::F32 {
                            vals.push(e.vals[i]);
                        }
                    }
                    off = next;
                }
                TileFormat::Dcsc => {
                    let (view, next) = dcsc::parse(buf, off, img.meta.valtype);
                    let e = dcsc::decode(&view, img.meta.valtype);
                    for (i, &(lr, lc)) in e.coords.iter().enumerate() {
                        coords.push((
                            (tr * t + lr as usize) as u32,
                            (view.tile_col as usize * t + lc as usize) as u32,
                        ));
                        if img.meta.valtype == ValueType::F32 {
                            vals.push(e.vals[i]);
                        }
                    }
                    off = next;
                }
            }
        }
    }
    // Global order: tiles are row-major but entries inside a tile row span
    // column blocks; sort for canonical comparison.
    let mut perm: Vec<usize> = (0..coords.len()).collect();
    perm.sort_unstable_by_key(|&i| coords[i]);
    let coords_sorted: Vec<_> = perm.iter().map(|&i| coords[i]).collect();
    let vals_sorted: Vec<_> = if vals.is_empty() {
        vals
    } else {
        perm.iter().map(|&i| vals[i]).collect()
    };
    (coords_sorted, vals_sorted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{erdos, rmat};

    fn sample_csr() -> Csr {
        let el = rmat::generate(10, 6_000, rmat::RmatParams::default(), 42);
        Csr::from_edgelist(&el)
    }

    #[test]
    fn build_and_decode_scsr() {
        let m = sample_csr();
        let img = TiledImage::build(&m, 256, TileFormat::Scsr);
        assert_eq!(img.meta.nnz as usize, m.nnz());
        let (coords, _) = decode_all(&img);
        let expect: Vec<(u32, u32)> = (0..m.nrows)
            .flat_map(|r| m.row(r).iter().map(move |&c| (r as u32, c)))
            .collect();
        assert_eq!(coords, expect);
    }

    #[test]
    fn build_and_decode_dcsc() {
        let m = sample_csr();
        let img = TiledImage::build(&m, 256, TileFormat::Dcsc);
        let (coords, _) = decode_all(&img);
        assert_eq!(coords.len(), m.nnz());
    }

    #[test]
    fn weighted_roundtrip() {
        let el = erdos::generate(500, 3_000, 3);
        let mut m = Csr::from_edgelist(&el);
        m.vals = Some((0..m.nnz()).map(|i| (i as f32).sin() + 2.0).collect());
        let img = TiledImage::build(&m, 128, TileFormat::Scsr);
        assert_eq!(img.meta.valtype, ValueType::F32);
        let (coords, vals) = decode_all(&img);
        assert_eq!(coords.len(), m.nnz());
        let expect_vals: Vec<f32> = (0..m.nrows)
            .flat_map(|r| m.row_vals(r).unwrap().iter().copied())
            .collect();
        assert_eq!(vals, expect_vals);
    }

    #[test]
    fn save_load_roundtrip() {
        let m = sample_csr();
        let img = TiledImage::build(&m, 512, TileFormat::Scsr);
        let dir = crate::util::tempdir();
        let p = dir.path().join("m.semm");
        img.save(&p).unwrap();
        let img2 = TiledImage::load(&p).unwrap();
        assert_eq!(img2.meta, img.meta);
        assert_eq!(img2.index, img.index);
        assert_eq!(img2.data, img.data);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), img.image_bytes());
        // from_bytes agrees with the file loader.
        let img3 = TiledImage::from_bytes(&std::fs::read(&p).unwrap()).unwrap();
        assert_eq!(img3.meta, img.meta);
        assert_eq!(img3.index, img.index);
        assert_eq!(img3.data, img.data);
        assert!(TiledImage::from_bytes(&std::fs::read(&p).unwrap()[..70]).is_err());
    }

    #[test]
    fn header_only_read() {
        let m = sample_csr();
        let img = TiledImage::build(&m, 512, TileFormat::Scsr);
        let dir = crate::util::tempdir();
        let p = dir.path().join("m.semm");
        img.save(&p).unwrap();
        let mut f = std::fs::File::open(&p).unwrap();
        let (meta, index, data_start) = read_header(&mut f).unwrap();
        assert_eq!(meta, img.meta);
        assert_eq!(index, img.index);
        assert_eq!(data_start, HEADER_LEN as u64 + index.len() as u64 * 16);
    }

    #[test]
    fn tile_rows_contiguous() {
        let m = sample_csr();
        let img = TiledImage::build(&m, 128, TileFormat::Scsr);
        let ntr = img.meta.n_tile_rows();
        // Index must tile the data area exactly, in order, no gaps.
        let mut expect_off = 0u64;
        for tr in 0..ntr {
            let (off, len) = img.index[tr];
            assert_eq!(off, expect_off);
            expect_off += len;
        }
        assert_eq!(expect_off, img.data.len() as u64);
        // Range read equals concatenation of single reads.
        if ntr >= 3 {
            let range = img.tile_rows(1, 3);
            let mut cat = img.tile_row(1).to_vec();
            cat.extend_from_slice(img.tile_row(2));
            assert_eq!(range, &cat[..]);
        }
    }

    #[test]
    fn scsr_beats_dcsc_on_powerlaw() {
        // Fig 2: SCSR should use 45–70% of DCSC on power-law graphs.
        let m = sample_csr();
        let s = TiledImage::build(&m, 256, TileFormat::Scsr).data_bytes() as f64;
        let d = TiledImage::build(&m, 256, TileFormat::Dcsc).data_bytes() as f64;
        let ratio = s / d;
        assert!(ratio < 0.85, "SCSR/DCSC ratio {ratio:.2}");
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::tempdir();
        let p = dir.path().join("junk");
        std::fs::write(&p, vec![0u8; 128]).unwrap();
        let mut f = std::fs::File::open(&p).unwrap();
        assert!(read_header(&mut f).is_err());
    }
}
