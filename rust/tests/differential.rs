//! Satellite differential tests: the tiled IM and SEM engines must agree
//! with the CSR baselines (`baselines::csr_spmm`) and the dense oracle
//! (`Csr::spmm_ref`) at dense widths 1, 4 and 32 — the paper's claim
//! that SEM matches IM from ~4 columns on rests on all four computing
//! the same numbers.

use sem_spmm::baselines::{csr_spmm, CsrSchedule, CsrSpmmOpts};
use sem_spmm::format::tiled::TiledImage;
use sem_spmm::format::{Csr, TileFormat};
use sem_spmm::graph::rmat;
use sem_spmm::io::{ShardedStore, StoreSpec};
use sem_spmm::matrix::{DenseMatrix, NumaConfig, NumaDense};
use sem_spmm::spmm::{engine, SemSource, Source, SpmmOpts};
use std::sync::Arc;

const WIDTHS: [usize; 3] = [1, 4, 32];

fn sample() -> Csr {
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 0xD1FF);
    Csr::from_edgelist(&el)
}

fn assert_close(tag: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * b.abs().max(1.0),
            "{tag}: mismatch at {i}: {a} vs {b}"
        );
    }
}

/// IM engine vs the dense oracle and the CSR baseline, widths 1/4/32.
#[test]
fn im_engine_matches_oracle_and_csr_baseline() {
    let m = sample();
    let img = Arc::new(TiledImage::build(&m, 256, TileFormat::Scsr));
    for p in WIDTHS {
        let x = DenseMatrix::random(m.ncols, p, p as u64 + 1);
        let oracle = m.spmm_ref(&x.data, p);

        let (im, stats) =
            engine::spmm_out(&Source::Mem(img.clone()), &x, &SpmmOpts::default()).unwrap();
        assert!(stats.tasks > 0);
        assert_close(&format!("IM vs oracle p={p}"), &im.data, &oracle);

        let nd = NumaDense::from_dense(&x, NumaConfig::for_tile(2, 256));
        let base = csr_spmm(&m, &nd, &CsrSpmmOpts::default());
        assert_close(&format!("CSR baseline vs oracle p={p}"), &base.data, &oracle);
        assert_close(&format!("IM vs CSR baseline p={p}"), &im.data, &base.data);
    }
}

/// SEM engine (streaming from the store) vs the same oracle, widths
/// 1/4/32 — the SEM≈IM equivalence the paper claims at >= 4 columns.
#[test]
fn sem_engine_matches_oracle_and_im() {
    let m = sample();
    let img = TiledImage::build(&m, 256, TileFormat::Scsr);
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("m.semm", &buf).unwrap();
    let img = Arc::new(img);

    for p in WIDTHS {
        let x = DenseMatrix::random(m.ncols, p, 100 + p as u64);
        let oracle = m.spmm_ref(&x.data, p);
        let (im, _) =
            engine::spmm_out(&Source::Mem(img.clone()), &x, &SpmmOpts::default()).unwrap();
        let sem_src = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
        let (sem, stats) = engine::spmm_out(&sem_src, &x, &SpmmOpts::default()).unwrap();
        assert!(stats.bytes_read > 0, "SEM must stream from the store");
        assert_close(&format!("SEM vs oracle p={p}"), &sem.data, &oracle);
        assert_close(&format!("SEM vs IM p={p}"), &sem.data, &im.data);
    }
}

/// Every CSR baseline schedule agrees with the tiled engine (width 4),
/// so the Fig 7/12 comparisons compare equal computations.
#[test]
fn all_csr_schedules_match_tiled_engine() {
    let m = sample();
    let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 7);
    let (engine_out, _) =
        engine::spmm_out(&Source::Mem(img), &x, &SpmmOpts::sequential()).unwrap();
    let nd = NumaDense::from_dense(&x, NumaConfig::single(m.ncols));
    for sched in [
        CsrSchedule::StaticRows,
        CsrSchedule::StaticNnz,
        CsrSchedule::DynamicChunks,
    ] {
        let opts = CsrSpmmOpts {
            threads: 3,
            schedule: sched,
            chunk: 128,
            vectorize: true,
        };
        let base = csr_spmm(&m, &nd, &opts);
        assert_close(&format!("{sched:?}"), &base.data, &engine_out.data);
    }
}

/// Tile-row cache differential: budget-0 (stream every pass) and
/// budget-∞ (everything resident after the first pass) runs must produce
/// **bit-identical** output across repeated iterations, and the cached
/// run must stop touching the store after its first pass — the cache
/// changes where bytes come from, never what they are.
#[test]
fn cached_sem_budget0_vs_infinite_bit_identical() {
    let m = sample();
    let img = TiledImage::build(&m, 256, TileFormat::Scsr);
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 21);
    let iters = 3;

    let run = |budget: u64| {
        let dir = sem_spmm::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        store.put("m.semm", &buf).unwrap();
        let sem = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
        let opts = SpmmOpts {
            threads: 3,
            cache_budget_bytes: budget,
            ..Default::default()
        };
        let mut outs = Vec::new();
        let mut logical = Vec::new();
        let mut physical = Vec::new();
        for _ in 0..iters {
            let (out, stats) = engine::spmm_out(&sem, &x, &opts).unwrap();
            outs.push(out.data);
            logical.push(stats.bytes_read);
            physical.push(stats.physical_bytes_read);
        }
        (outs, logical, physical)
    };

    let (cold_outs, cold_logical, _) = run(0);
    let (warm_outs, warm_logical, warm_physical) = run(u64::MAX);

    for i in 0..iters {
        assert_eq!(
            cold_outs[i], warm_outs[i],
            "iteration {i}: cached output differs from uncached"
        );
    }
    // Uncached: every iteration streams the matrix.
    assert!(cold_logical.iter().all(|&b| b > 0));
    // Cached: the first iteration streams, the rest are entirely served
    // from memory — zero logical requests, zero physical sub-reads.
    assert!(warm_logical[0] > 0 && warm_physical[0] > 0);
    for i in 1..iters {
        assert_eq!(warm_logical[i], 0, "iteration {i} issued store reads");
        assert_eq!(warm_physical[i], 0, "iteration {i} did physical reads");
    }
}

/// Weighted matrices take the same differential path (width 4).
#[test]
fn weighted_differential_width4() {
    let mut m = sample();
    let mut rng = sem_spmm::util::Xoshiro256::new(9);
    m.vals = Some((0..m.nnz()).map(|_| rng.next_f32() * 2.0 - 1.0).collect());
    let img = Arc::new(TiledImage::build(&m, 256, TileFormat::Scsr));
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 11);
    let oracle = m.spmm_ref(&x.data, p);
    let (im, _) = engine::spmm_out(&Source::Mem(img), &x, &SpmmOpts::default()).unwrap();
    assert_close("weighted IM vs oracle", &im.data, &oracle);
    let nd = NumaDense::from_dense(&x, NumaConfig::for_tile(2, 256));
    let base = csr_spmm(&m, &nd, &CsrSpmmOpts::default());
    assert_close("weighted CSR vs oracle", &base.data, &oracle);
}
