//! Satellite differential tests: the tiled IM and SEM engines must agree
//! with the CSR baselines (`baselines::csr_spmm`) and the dense oracle
//! (`Csr::spmm_ref`) at dense widths 1, 4 and 32 — the paper's claim
//! that SEM matches IM from ~4 columns on rests on all four computing
//! the same numbers.
//!
//! The delta-layer battery at the bottom extends the same discipline to
//! dynamic graphs: a sweep over base-plus-edit-runs must be
//! **bit-identical** to a full reconversion of the mutated edge list,
//! at every LSM stage and in every semiring.

use sem_spmm::baselines::{csr_spmm, CsrSchedule, CsrSpmmOpts};
use sem_spmm::format::tiled::TiledImage;
use sem_spmm::format::{Csr, TileFormat};
use sem_spmm::graph::{rmat, sbm};
use sem_spmm::io::{ShardedStore, StoreSpec};
use sem_spmm::matrix::{DenseMatrix, NumaConfig, NumaDense};
use sem_spmm::spmm::{engine, run_pass, SemSource, Source, SpmmOpts, StreamPass};
use std::sync::Arc;

const WIDTHS: [usize; 3] = [1, 4, 32];

fn sample() -> Csr {
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 0xD1FF);
    Csr::from_edgelist(&el)
}

fn assert_close(tag: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * b.abs().max(1.0),
            "{tag}: mismatch at {i}: {a} vs {b}"
        );
    }
}

/// IM engine vs the dense oracle and the CSR baseline, widths 1/4/32.
#[test]
fn im_engine_matches_oracle_and_csr_baseline() {
    let m = sample();
    let img = Arc::new(TiledImage::build(&m, 256, TileFormat::Scsr));
    for p in WIDTHS {
        let x = DenseMatrix::random(m.ncols, p, p as u64 + 1);
        let oracle = m.spmm_ref(&x.data, p);

        let (im, stats) =
            engine::spmm_out(&Source::Mem(img.clone()), &x, &SpmmOpts::default()).unwrap();
        assert!(stats.tasks > 0);
        assert_close(&format!("IM vs oracle p={p}"), &im.data, &oracle);

        let nd = NumaDense::from_dense(&x, NumaConfig::for_tile(2, 256));
        let base = csr_spmm(&m, &nd, &CsrSpmmOpts::default());
        assert_close(&format!("CSR baseline vs oracle p={p}"), &base.data, &oracle);
        assert_close(&format!("IM vs CSR baseline p={p}"), &im.data, &base.data);
    }
}

/// SEM engine (streaming from the store) vs the same oracle, widths
/// 1/4/32 — the SEM≈IM equivalence the paper claims at >= 4 columns.
#[test]
fn sem_engine_matches_oracle_and_im() {
    let m = sample();
    let img = TiledImage::build(&m, 256, TileFormat::Scsr);
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("m.semm", &buf).unwrap();
    let img = Arc::new(img);

    for p in WIDTHS {
        let x = DenseMatrix::random(m.ncols, p, 100 + p as u64);
        let oracle = m.spmm_ref(&x.data, p);
        let (im, _) =
            engine::spmm_out(&Source::Mem(img.clone()), &x, &SpmmOpts::default()).unwrap();
        let sem_src = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
        let (sem, stats) = engine::spmm_out(&sem_src, &x, &SpmmOpts::default()).unwrap();
        assert!(stats.bytes_read > 0, "SEM must stream from the store");
        assert_close(&format!("SEM vs oracle p={p}"), &sem.data, &oracle);
        assert_close(&format!("SEM vs IM p={p}"), &sem.data, &im.data);
    }
}

/// Every CSR baseline schedule agrees with the tiled engine (width 4),
/// so the Fig 7/12 comparisons compare equal computations.
#[test]
fn all_csr_schedules_match_tiled_engine() {
    let m = sample();
    let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 7);
    let (engine_out, _) =
        engine::spmm_out(&Source::Mem(img), &x, &SpmmOpts::sequential()).unwrap();
    let nd = NumaDense::from_dense(&x, NumaConfig::single(m.ncols));
    for sched in [
        CsrSchedule::StaticRows,
        CsrSchedule::StaticNnz,
        CsrSchedule::DynamicChunks,
    ] {
        let opts = CsrSpmmOpts {
            threads: 3,
            schedule: sched,
            chunk: 128,
            vectorize: true,
        };
        let base = csr_spmm(&m, &nd, &opts);
        assert_close(&format!("{sched:?}"), &base.data, &engine_out.data);
    }
}

/// Tile-row cache differential: budget-0 (stream every pass) and
/// budget-∞ (everything resident after the first pass) runs must produce
/// **bit-identical** output across repeated iterations, and the cached
/// run must stop touching the store after its first pass — the cache
/// changes where bytes come from, never what they are.
#[test]
fn cached_sem_budget0_vs_infinite_bit_identical() {
    let m = sample();
    let img = TiledImage::build(&m, 256, TileFormat::Scsr);
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 21);
    let iters = 3;

    let run = |budget: u64| {
        let dir = sem_spmm::util::tempdir();
        let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
        store.put("m.semm", &buf).unwrap();
        let sem = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
        let opts = SpmmOpts {
            threads: 3,
            cache_budget_bytes: budget,
            ..Default::default()
        };
        let mut outs = Vec::new();
        let mut logical = Vec::new();
        let mut physical = Vec::new();
        for _ in 0..iters {
            let (out, stats) = engine::spmm_out(&sem, &x, &opts).unwrap();
            outs.push(out.data);
            logical.push(stats.bytes_read);
            physical.push(stats.physical_bytes_read);
        }
        (outs, logical, physical)
    };

    let (cold_outs, cold_logical, _) = run(0);
    let (warm_outs, warm_logical, warm_physical) = run(u64::MAX);

    for i in 0..iters {
        assert_eq!(
            cold_outs[i], warm_outs[i],
            "iteration {i}: cached output differs from uncached"
        );
    }
    // Uncached: every iteration streams the matrix.
    assert!(cold_logical.iter().all(|&b| b > 0));
    // Cached: the first iteration streams, the rest are entirely served
    // from memory — zero logical requests, zero physical sub-reads.
    assert!(warm_logical[0] > 0 && warm_physical[0] > 0);
    for i in 1..iters {
        assert_eq!(warm_logical[i], 0, "iteration {i} issued store reads");
        assert_eq!(warm_physical[i], 0, "iteration {i} did physical reads");
    }
}

/// Transpose-path differential: the fused scatter computation of `Aᵀ·Y`
/// from a sweep of A's single image must agree with the gather engine
/// running over an **explicitly converted transpose image** — on an RMAT
/// and an SBM graph, through a 4-shard striped store, under a partial
/// tile-row-cache budget (second pass exercises cache hits + mixed
/// groups), within 1e-4.
#[test]
fn transpose_pass_matches_transposed_image() {
    let rmat_m = Csr::from_edgelist(&rmat::generate(
        10,
        12_000,
        rmat::RmatParams::default(),
        0x7A55,
    ));
    let sbm_m = Csr::from_edgelist(&sbm::generate(
        sbm::SbmParams {
            num_verts: 1 << 10,
            num_edges: 14_000,
            num_clusters: 16,
            in_out: 8.0,
            clustered_order: true,
        },
        0x5B31,
    ));
    for (name, m) in [("rmat", rmat_m), ("sbm", sbm_m)] {
        let mt = m.transpose();
        let img = TiledImage::build(&m, 128, TileFormat::Scsr);
        let img_t = TiledImage::build(&mt, 128, TileFormat::Scsr);
        let dir = sem_spmm::util::tempdir();
        let store = ShardedStore::open(StoreSpec {
            dir: dir.path().to_path_buf(),
            shards: 4,
            stripe_bytes: 4096,
            read_gbps: None,
            write_gbps: None,
            latency_us: 0,
            parity: false,
        })
        .unwrap();
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        store.put("a.semm", &buf).unwrap();
        let mut buf_t = Vec::new();
        img_t.write_to(&mut buf_t).unwrap();
        store.put("at.semm", &buf_t).unwrap();

        let p = 4;
        let y = DenseMatrix::random(m.nrows, p, 0xD1D);
        let opts = SpmmOpts {
            threads: 4,
            io_workers: 2,
            // Partial budget: only the densest tile rows stay resident,
            // so the second pass mixes cache frames with store reads.
            cache_budget_bytes: img.data_bytes() * 2 / 3,
            ..Default::default()
        };
        // Reference: gather over the explicitly converted Aᵀ image.
        let src_t = Source::Sem(SemSource::open(&store, "at.semm").unwrap());
        let (want, _) = engine::spmm_out(&src_t, &y, &opts).unwrap();

        let src = Source::Sem(SemSource::open(&store, "a.semm").unwrap());
        let ncfg = engine::numa_config(128, m.nrows.max(m.ncols), &opts);
        let ynd = NumaDense::from_dense(&y, ncfg);
        for pass_i in 0..2 {
            let out = NumaDense::zeros(m.ncols, p, ncfg);
            let pass = StreamPass::new().transpose(&ynd, &out);
            let stats = run_pass(&src, &pass, &opts).unwrap().stats;
            if pass_i == 0 {
                assert!(stats.bytes_read > 0, "{name}: first pass must stream");
            } else {
                assert!(stats.cache_hits > 0, "{name}: second pass must hit cache");
            }
            let got = out.to_dense();
            for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "{name} pass {pass_i}: row-major index {i}: {a} vs {b}"
                );
            }
        }
        // The striped data area really fanned out over all shards.
        for k in 0..store.num_shards() {
            assert!(store.shard(k).stats.read_reqs.get() > 0, "{name}: shard {k} idle");
        }
    }
}

/// Semiring-refactor guard: the streaming engine is generic over the
/// (⊕, ⊗) ring, and its arithmetic instantiation must be
/// **bit-identical** to the compat `run_pass` entry point — same fused
/// forward + transpose pass, same striped SEM store, same thread count,
/// on an RMAT and an SBM graph. The generic machinery may change where
/// the adds come from, never what they compute.
#[test]
fn arith_ring_instantiation_is_bit_identical() {
    use sem_spmm::spmm::{run_pass_ring, Arith, OutputSink};
    let rmat_m = sample();
    let sbm_m = Csr::from_edgelist(&sbm::generate(
        sbm::SbmParams {
            num_verts: 1 << 10,
            num_edges: 14_000,
            num_clusters: 16,
            in_out: 8.0,
            clustered_order: true,
        },
        0xA12E,
    ));
    for (name, m) in [("rmat", rmat_m), ("sbm", sbm_m)] {
        let img = TiledImage::build(&m, 128, TileFormat::Scsr);
        let dir = sem_spmm::util::tempdir();
        let store = ShardedStore::open(StoreSpec {
            dir: dir.path().to_path_buf(),
            shards: 4,
            stripe_bytes: 4096,
            read_gbps: None,
            write_gbps: None,
            latency_us: 0,
            parity: false,
        })
        .unwrap();
        let mut buf = Vec::new();
        img.write_to(&mut buf).unwrap();
        store.put("a.semm", &buf).unwrap();
        let src = Source::Sem(SemSource::open(&store, "a.semm").unwrap());

        let p = 4;
        let opts = SpmmOpts {
            threads: 3,
            ..Default::default()
        };
        let ncfg = engine::numa_config(128, m.nrows.max(m.ncols), &opts);
        let x = NumaDense::from_dense(&DenseMatrix::random(m.ncols, p, 0x51), ncfg);
        let y = NumaDense::from_dense(&DenseMatrix::random(m.nrows, p, 0x52), ncfg);

        let run = |explicit_ring: bool| {
            let fwd = NumaDense::zeros(m.nrows, p, ncfg);
            let tr = NumaDense::zeros(m.ncols, p, ncfg);
            let pass = StreamPass::new()
                .forward(&x, OutputSink::Mem(&fwd))
                .transpose(&y, &tr);
            let r = if explicit_ring {
                run_pass_ring::<Arith>(&src, &pass, &opts).unwrap()
            } else {
                run_pass(&src, &pass, &opts).unwrap()
            };
            assert!(r.stats.bytes_read > 0, "{name}: pass must stream");
            (fwd.to_dense().data, tr.to_dense().data)
        };
        let (fwd_compat, tr_compat) = run(false);
        let (fwd_ring, tr_ring) = run(true);
        assert_eq!(fwd_compat, fwd_ring, "{name}: forward op diverged");
        assert_eq!(tr_compat, tr_ring, "{name}: transpose op diverged");

        // And the numbers are still the engine's numbers: spmm_out over
        // the same source must reproduce the forward block bit for bit.
        let (out, _) = engine::spmm_out(&src, &x.to_dense(), &opts).unwrap();
        assert_eq!(out.data, fwd_compat, "{name}: engine front door diverged");
    }
}

/// Dynamic-graph differential: a weighted RMAT base on a 4-shard
/// striped store takes three committed batches of mixed edge edits
/// (inserts, deletes, weight updates), mirrored into a `BTreeMap`
/// reference model. At each LSM stage — (1) base + three uncompacted
/// runs, (2) base + one compacted run, (3) the post-major-compaction
/// base — a streaming sweep over the merged [`DeltaSource`] view must
/// be **bit-identical** to an in-memory sweep of the fully reconverted
/// mutated matrix, in all four semirings, under a partial tile-row
/// cache budget. Stage 3 additionally proves the swapped base object is
/// byte-identical to the reconverted image.
#[test]
fn delta_source_matches_full_reconversion_at_all_lsm_stages() {
    use sem_spmm::format::delta::DeltaOp;
    use sem_spmm::io::{DeltaConfig, DeltaStore};
    use sem_spmm::spmm::{Arith, DeltaSource, MinPlus, MinSelect, OrAnd};
    use std::collections::BTreeMap;

    let tile = 128;
    let mut m = sample();
    let mut rng = sem_spmm::util::Xoshiro256::new(0xDE17A);
    m.vals = Some((0..m.nnz()).map(|_| rng.next_f32() * 2.0 + 0.5).collect());
    let n = m.nrows;

    // Reference model of the live edge set.
    let mut model: BTreeMap<(u32, u32), f32> = BTreeMap::new();
    for r in 0..m.nrows {
        for k in m.indptr[r] as usize..m.indptr[r + 1] as usize {
            model.insert((r as u32, m.indices[k]), m.vals.as_ref().unwrap()[k]);
        }
    }

    let img = TiledImage::build(&m, tile, TileFormat::Scsr);
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec {
        dir: dir.path().to_path_buf(),
        shards: 4,
        stripe_bytes: 4096,
        read_gbps: None,
        write_gbps: None,
        latency_us: 0,
        parity: false,
    })
    .unwrap();
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("g.semm", &buf).unwrap();

    // Triggers disabled: this test drives each compaction stage by hand.
    let ds = DeltaStore::open(
        &store,
        "g.semm",
        DeltaConfig {
            buffer_bytes: 64 << 20,
            compact_runs: 1 << 20,
            major_compact_ratio: 1e12,
        },
    )
    .unwrap();

    // Three committed batches of mixed edits.
    for batch in 0..3usize {
        let keys: Vec<(u32, u32)> = model.keys().copied().collect();
        for i in 0..150usize {
            let op = match (batch + i) % 3 {
                0 => {
                    // Insert (possibly overwriting an existing edge).
                    let r = rng.below(n as u64) as u32;
                    let c = rng.below(n as u64) as u32;
                    let w = rng.next_f32() + 0.25;
                    model.insert((r, c), w);
                    DeltaOp::upsert(r, c, w)
                }
                1 => {
                    // Delete (idempotent when hit twice).
                    let (r, c) = keys[rng.below_usize(keys.len())];
                    model.remove(&(r, c));
                    DeltaOp::delete(r, c)
                }
                _ => {
                    // Weight update (may resurrect a deleted edge).
                    let (r, c) = keys[rng.below_usize(keys.len())];
                    let w = rng.next_f32() * 3.0 + 0.1;
                    model.insert((r, c), w);
                    DeltaOp::upsert(r, c, w)
                }
            };
            ds.stage(op).unwrap();
        }
        let rep = ds.commit().unwrap();
        assert_eq!(rep.ops, 150);
        assert_eq!(rep.runs, batch + 1, "no auto-compaction in this test");
    }

    // Full reconversion of the mutated edge set (the oracle image).
    let pairs: Vec<(u32, u32)> = model.keys().copied().collect();
    let mut mutated = Csr::from_sorted_pairs(n, n, &pairs);
    mutated.vals = Some(model.values().copied().collect());
    let want_img = Arc::new(TiledImage::build(&mutated, tile, TileFormat::Scsr));

    let opts = SpmmOpts {
        threads: 3,
        io_workers: 2,
        // Partial budget: merged sweeps mix cached and streamed rows.
        cache_budget_bytes: img.data_bytes() * 2 / 3,
        ..Default::default()
    };

    fn sweep<S: sem_spmm::spmm::Semiring>(
        src: &Source,
        n: usize,
        opts: &SpmmOpts,
    ) -> Vec<f32> {
        let p = 4;
        let ncfg = engine::numa_config(128, n, opts);
        let x = NumaDense::from_dense(&DenseMatrix::random(n, p, 0xBEEF), ncfg);
        let out = NumaDense::zeros(n, p, ncfg);
        let pass =
            StreamPass::<S>::new().forward(&x, sem_spmm::spmm::OutputSink::Mem(&out));
        sem_spmm::spmm::run_pass_ring::<S>(src, &pass, opts).unwrap();
        out.to_dense().data
    }

    let check_stage = |stage: &str| {
        let dsrc = Source::Delta(DeltaSource::open(&store, "g.semm").unwrap());
        let msrc = Source::Mem(want_img.clone());
        assert_eq!(
            sweep::<Arith>(&dsrc, n, &opts),
            sweep::<Arith>(&msrc, n, &opts),
            "{stage}: Arith diverged from reconversion"
        );
        assert_eq!(
            sweep::<MinPlus>(&dsrc, n, &opts),
            sweep::<MinPlus>(&msrc, n, &opts),
            "{stage}: MinPlus diverged from reconversion"
        );
        assert_eq!(
            sweep::<OrAnd>(&dsrc, n, &opts),
            sweep::<OrAnd>(&msrc, n, &opts),
            "{stage}: OrAnd diverged from reconversion"
        );
        assert_eq!(
            sweep::<MinSelect>(&dsrc, n, &opts),
            sweep::<MinSelect>(&msrc, n, &opts),
            "{stage}: MinSelect diverged from reconversion"
        );
    };

    check_stage("stage 1 (base + 3 uncompacted runs)");
    assert!(ds.compact_runs().unwrap());
    assert_eq!(ds.manifest().unwrap().runs.len(), 1);
    check_stage("stage 2 (base + compacted run)");
    assert!(ds.major_compact().unwrap());
    let man = ds.manifest().unwrap();
    assert!(man.runs.is_empty());
    assert_eq!(man.base_version, 1);
    check_stage("stage 3 (post-major-compaction base)");
    // The swapped base is byte-identical to the reconverted image.
    let mut want_bytes = Vec::new();
    want_img.write_to(&mut want_bytes).unwrap();
    assert_eq!(
        store.read_object_unmetered(&man.base).unwrap(),
        want_bytes,
        "major compaction must write the canonical reconverted image"
    );
}

/// Weighted matrices take the same differential path (width 4).
#[test]
fn weighted_differential_width4() {
    let mut m = sample();
    let mut rng = sem_spmm::util::Xoshiro256::new(9);
    m.vals = Some((0..m.nnz()).map(|_| rng.next_f32() * 2.0 - 1.0).collect());
    let img = Arc::new(TiledImage::build(&m, 256, TileFormat::Scsr));
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 11);
    let oracle = m.spmm_ref(&x.data, p);
    let (im, _) = engine::spmm_out(&Source::Mem(img), &x, &SpmmOpts::default()).unwrap();
    assert_close("weighted IM vs oracle", &im.data, &oracle);
    let nd = NumaDense::from_dense(&x, NumaConfig::for_tile(2, 256));
    let base = csr_spmm(&m, &nd, &CsrSpmmOpts::default());
    assert_close("weighted CSR vs oracle", &base.data, &oracle);
}

/// SIMD differential: forced-on vs forced-off SIMD arms over a 4-shard
/// striped store, SCSR and DCSC images, weighted and binary matrices,
/// `p ∈ {1, 2, 4, 8, 16}`. The forward gather and the SCSR scatter use
/// separate mul-then-add vector math — same IEEE roundings as the
/// scalar loops — so those paths must be **bit-identical**; only the
/// DCSC transpose arm keeps an FMA accumulator and is allowed its
/// documented ≲1-ulp-per-entry drift (2e-6 relative). On a CPU without
/// a vector arm (or under `SEM_SPMM_SIMD=off`) both runs resolve to the
/// scalar loops and the identity is trivially exact — the CI off-leg is
/// supposed to take that branch.
#[test]
fn simd_on_vs_off_differential_over_striped_store() {
    use sem_spmm::spmm::SimdMode;

    let binary = sample();
    let mut weighted = sample();
    let mut rng = sem_spmm::util::Xoshiro256::new(0x51D);
    weighted.vals = Some((0..weighted.nnz()).map(|_| rng.next_f32() * 2.0 - 1.0).collect());

    for (mname, m) in [("binary", &binary), ("weighted", &weighted)] {
        for fmt in [TileFormat::Scsr, TileFormat::Dcsc] {
            let img = TiledImage::build(m, 128, fmt);
            let dir = sem_spmm::util::tempdir();
            let store = ShardedStore::open(StoreSpec {
                dir: dir.path().to_path_buf(),
                shards: 4,
                stripe_bytes: 4096,
                read_gbps: None,
                write_gbps: None,
                latency_us: 0,
                parity: false,
            })
            .unwrap();
            let mut buf = Vec::new();
            img.write_to(&mut buf).unwrap();
            store.put("s.semm", &buf).unwrap();
            let src = Source::Sem(SemSource::open(&store, "s.semm").unwrap());
            let tag = |p: usize| format!("{mname}/{fmt:?} p={p}");

            for p in [1usize, 2, 4, 8, 16] {
                let opts = |mode: SimdMode| SpmmOpts {
                    threads: 3,
                    io_workers: 2,
                    simd: mode,
                    ..Default::default()
                };
                // Forward gather: bit-identical.
                let x = DenseMatrix::random(m.ncols, p, 0xF0 + p as u64);
                let (off, _) = engine::spmm_out(&src, &x, &opts(SimdMode::Off)).unwrap();
                let (on, _) = engine::spmm_out(&src, &x, &opts(SimdMode::On)).unwrap();
                assert_eq!(off.data, on.data, "{}: forward gather diverged", tag(p));

                // Transpose scatter: SCSR exact, DCSC within FMA drift.
                let y = DenseMatrix::random(m.nrows, p, 0x1F0 + p as u64);
                let scatter = |mode: SimdMode| {
                    let o = opts(mode);
                    let ncfg = engine::numa_config(128, m.nrows.max(m.ncols), &o);
                    let ynd = NumaDense::from_dense(&y, ncfg);
                    let out = NumaDense::zeros(m.ncols, p, ncfg);
                    let pass = StreamPass::new().transpose(&ynd, &out);
                    run_pass(&src, &pass, &o).unwrap();
                    out.to_dense().data
                };
                let t_off = scatter(SimdMode::Off);
                let t_on = scatter(SimdMode::On);
                match fmt {
                    TileFormat::Scsr => {
                        assert_eq!(t_off, t_on, "{}: SCSR scatter diverged", tag(p));
                    }
                    TileFormat::Dcsc => {
                        for (i, (a, b)) in t_on.iter().zip(&t_off).enumerate() {
                            assert!(
                                (a - b).abs() <= 2e-6 * b.abs().max(1.0),
                                "{}: DCSC scatter index {i}: {a} vs {b}",
                                tag(p)
                            );
                        }
                    }
                }
            }
            // Guard against a silent fallback: with SIMD forced off, the
            // engine must report a scalar kernel arm in its stats. An
            // explicit `SEM_SPMM_SIMD=on` in the environment overrides
            // the per-run request, so only assert when Off is effective.
            if sem_spmm::spmm::simd::effective_mode(SimdMode::Off) == SimdMode::Off {
                let x = DenseMatrix::random(m.ncols, 8, 3);
                let (_, stats) = engine::spmm_out(&src, &x, &SpmmOpts {
                    simd: SimdMode::Off,
                    ..SpmmOpts::sequential()
                })
                .unwrap();
                assert!(
                    stats.per_op.iter().all(|o| o.kernel == "scalar-w"),
                    "{mname}/{fmt:?}: forced-off run reported {:?}",
                    stats.per_op.iter().map(|o| o.kernel).collect::<Vec<_>>()
                );
            }
        }
    }
}
