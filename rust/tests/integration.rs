//! Cross-module integration tests: the full pipelines a user actually
//! runs, wired through the real store, real conversion, real engine —
//! including the PJRT runtime when artifacts are built.

use sem_spmm::apps::{eigen, nmf, pagerank};
use sem_spmm::coordinator::{Catalog, MemBudget, PassPlan};
use sem_spmm::format::{convert, Csr, TileFormat};
use sem_spmm::graph::{registry, rmat};
use sem_spmm::io::{ShardedStore, StoreSpec};
use sem_spmm::matrix::{DenseMatrix, SemDense};
use sem_spmm::spmm::{engine, SemSource, Source, SpmmOpts};
use std::sync::Arc;

fn throttled_store(dir: &std::path::Path) -> Arc<ShardedStore> {
    // A deliberately slow store so SEM paths are really I/O-bound.
    ShardedStore::open(StoreSpec::slow_ssd(dir.join("store"), 0.8)).unwrap()
}

#[test]
fn pipeline_generate_convert_multiply_verify() {
    // Graph → CSR image → streamed conversion → SEM SpMM → exact check.
    let dir = sem_spmm::util::tempdir();
    let store = throttled_store(dir.path());
    let el = rmat::generate(11, 30_000, rmat::RmatParams::default(), 5);
    let m = Csr::from_edgelist(&el);
    convert::put_csr_image(&store, "g.csr", &m).unwrap();
    let report = convert::convert(&store, "g.csr", "g.semm", 512, TileFormat::Scsr).unwrap();
    assert!(report.io_gbps > 0.0);

    let sem = SemSource::open(&store, "g.semm").unwrap();
    let x = DenseMatrix::random(m.ncols, 4, 9);
    let expect = m.spmm_ref(&x.data, 4);
    let (got, stats) =
        engine::spmm_out(&Source::Sem(sem), &x, &SpmmOpts::default()).unwrap();
    assert!(stats.bytes_read > 0);
    for (a, b) in got.data.iter().zip(&expect) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn catalog_to_all_three_applications() {
    // One catalog feeds PageRank, the eigensolver and NMF.
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let catalog = Catalog::new(store.clone(), 512);
    let opts = SpmmOpts {
        threads: 3,
        ..Default::default()
    };

    // PageRank on the directed twitter stand-in.
    let spec = registry::by_name("twitter").unwrap().shrunk(11);
    let imgs = catalog.ensure(&spec).unwrap();
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let (pr, _) = pagerank::pagerank(
        &src,
        &imgs.degrees,
        &store,
        &pagerank::PageRankConfig {
            iterations: 8,
            spmm: opts.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(pr.len(), imgs.num_verts);
    assert!(pr.iter().all(|&v| v > 0.0));

    // Eigensolver on the undirected friendster stand-in.
    let spec = registry::by_name("friendster").unwrap().shrunk(10);
    let imgs = catalog.ensure(&spec).unwrap();
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let res = eigen::eigensolve(
        &src,
        &store,
        &eigen::EigenConfig {
            nev: 3,
            block: 1,
            subspace: 12,
            tol: 1e-4,
            spmm: opts.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(res.eigenvalues.len(), 3);
    assert!(res.eigenvalues[0] >= res.eigenvalues[1]);

    // NMF on the directed rmat-40 stand-in, panelized — one stored
    // image of A, the transpose product comes out of the fused sweep.
    let spec = registry::by_name("rmat-40").unwrap().shrunk(10);
    let imgs = catalog.ensure(&spec).unwrap();
    assert!(
        !store.exists(&imgs.adj_t),
        "NMF must not need a transpose image on the store"
    );
    let a = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let res = nmf::nmf(
        &a,
        &store,
        &nmf::NmfConfig {
            k: 8,
            iterations: 3,
            cols_in_mem: 2,
            spmm: opts,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(res.residuals.windows(2).all(|w| w[1] <= w[0] * 1.01));
    // Fused: one streaming pass per panel pair per iteration.
    assert_eq!(res.sparse_passes, 3 * 4);
}

#[test]
fn catalog_to_traversal_apps_and_spgemm() {
    // One catalog feeds the three semiring traversal apps and the
    // out-of-core A·A SpGEMM, all streaming from the store.
    use sem_spmm::apps::{bfs, labelprop, sssp};
    use sem_spmm::spmm::spgemm;
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let catalog = Catalog::new(store.clone(), 512);
    let opts = SpmmOpts {
        threads: 3,
        ..Default::default()
    };

    // Directed twitter stand-in: BFS levels match the queue reference,
    // and binary-weight SSSP distances are exactly the BFS hop counts
    // with a valid shortest-path tree.
    let spec = registry::by_name("twitter").unwrap().shrunk(10);
    let el = spec.build();
    let imgs = catalog.ensure(&spec).unwrap();
    let root = 0u32;
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let (levels, bstats) = bfs::bfs(
        &src,
        root,
        &bfs::BfsConfig {
            spmm: opts.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(bstats.bytes_read > 0, "BFS must stream from the store");
    assert_eq!(levels, bfs::bfs_ref(imgs.num_verts, &el.edges, root));

    let (dists, parents, sstats) = sssp::sssp(
        &src,
        root,
        &sssp::SsspConfig {
            spmm: opts.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(sstats.converged);
    for (v, (&d, &l)) in dists.iter().zip(&levels).enumerate() {
        if l >= 0 {
            assert_eq!(d, l as f32, "vertex {v}: hop count vs BFS level");
        } else {
            assert!(d.is_infinite(), "vertex {v} unreached");
        }
    }
    for v in 0..imgs.num_verts {
        if levels[v] > 0 {
            let p = parents[v];
            assert!(p >= 0, "reached vertex {v} has no tree parent");
            assert_eq!(levels[p as usize] + 1, levels[v], "vertex {v} parent depth");
        }
    }

    // Undirected friendster stand-in: min-label components against
    // union-find over the same edge list.
    let spec = registry::by_name("friendster").unwrap().shrunk(10);
    let el = spec.build();
    let imgs = catalog.ensure(&spec).unwrap();
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let (labels, cstats) = labelprop::connected_components(
        &src,
        &labelprop::LabelPropConfig {
            spmm: opts.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(cstats.converged);
    assert_eq!(labels, labelprop::cc_ref(imgs.num_verts, &el.edges));

    // Out-of-core A·A on the twitter stand-in: intermediate runs spill
    // through the store, and streaming A from the store (SEM) yields the
    // same product as reading it from memory (IM).
    let spec = registry::by_name("twitter").unwrap().shrunk(10);
    let imgs = catalog.ensure(&spec).unwrap();
    let b_img = catalog.load_adj(&imgs).unwrap();
    let gopts = spgemm::SpgemmOpts {
        threads: 2,
        ..Default::default()
    };
    let sem = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let prod_sem = spgemm::spgemm(&sem, &b_img, &store, "aa.sem.runs", &gopts).unwrap();
    let im = Source::Mem(Arc::new(catalog.load_adj(&imgs).unwrap()));
    let prod_im = spgemm::spgemm(&im, &b_img, &store, "aa.im.runs", &gopts).unwrap();
    assert!(prod_sem.stats.runs > 0, "A·A never spilled a run");
    assert!(prod_sem.stats.nnz > 0);
    assert_eq!(prod_sem.csr, prod_im.csr, "SEM product diverged from IM");
}

#[test]
fn vertical_partitioning_under_budget_is_exact() {
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 8);
    let m = Csr::from_edgelist(&el);
    let img = sem_spmm::format::tiled::TiledImage::build(&m, 256, TileFormat::Scsr);
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("m.semm", &buf).unwrap();

    let n = m.nrows;
    let p = 16usize;
    let x = DenseMatrix::random(n, p, 3);
    let expect = m.spmm_ref(&x.data, p);
    // Budget: 3 columns fit → 6 passes of 3 (last narrower).
    let budget = MemBudget::new((n * 4 * 3) as u64 + 512);
    let plan = PassPlan::plan(n, p, &budget);
    let input = SemDense::create(&store, "vx", n, p, plan.panel_cols).unwrap();
    input.store_all(&x).unwrap();
    let mut output = SemDense::create(&store, "vy", n, p, plan.panel_cols).unwrap();
    let sem = SemSource::open(&store, "m.semm").unwrap();
    let report = sem_spmm::coordinator::spmm_vert(
        &Source::Sem(sem),
        &input,
        &mut output,
        &budget,
        &SpmmOpts {
            threads: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.passes > 1);
    let got = output.load_all().unwrap();
    for (a, b) in got.data.iter().zip(&expect) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn dense_backend_composes_with_engine() {
    // SEM SpMM feeding the backend's blocked gram — L3 + backend in one
    // flow. Uses the AOT/PJRT backend when built with `--features pjrt`
    // and artifacts exist; the native backend (same block contract)
    // otherwise.
    let be = sem_spmm::runtime::backend_from_env()
        .unwrap_or_else(sem_spmm::runtime::default_backend);
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let catalog = Catalog::new(store, 512);
    let spec = registry::by_name("rmat-40").unwrap().shrunk(10);
    let imgs = catalog.ensure(&spec).unwrap();
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let x = DenseMatrix::random(imgs.num_verts, 8, 4);
    let (y, _) = engine::spmm_out(&src, &x, &SpmmOpts::default()).unwrap();
    // Gram of the SpMM result via the PJRT artifact vs native.
    let g_xla = be.gram(&y).unwrap();
    let g_native = sem_spmm::matrix::ops::gram(&y);
    let scale = g_native.data.iter().fold(1f32, |a, &v| a.max(v.abs()));
    assert!(g_xla.max_abs_diff(&g_native) < 1e-3 * scale);
}

#[test]
fn sem_is_io_bound_on_slow_store_and_spmm_amortizes() {
    // The paper's crossover: on a slow store SpMV is I/O bound; widening
    // the dense matrix amortizes the same bytes over more compute, so
    // wall time grows far slower than the compute width.
    let dir = sem_spmm::util::tempdir();
    let store = throttled_store(dir.path());
    let catalog = Catalog::new(store.clone(), 512);
    let spec = registry::by_name("rmat-160").unwrap().shrunk(11);
    let imgs = catalog.ensure(&spec).unwrap();
    let opts = SpmmOpts::default();
    let t = |p: usize| {
        let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
        let x = DenseMatrix::random(imgs.num_verts, p, 1);
        let (_, stats) = engine::spmm_out(&src, &x, &opts).unwrap();
        stats.secs
    };
    let t1 = t(1).min(t(1));
    let t8 = t(8).min(t(8));
    assert!(
        t8 < 4.0 * t1,
        "8x compute should cost <4x wall when I/O bound: t1={t1:.3} t8={t8:.3}"
    );
}

#[test]
fn throttle_is_enforced_end_to_end() {
    // SpMV over a 0.2 GB/s store cannot exceed the configured bandwidth.
    let dir = sem_spmm::util::tempdir();
    let store =
        ShardedStore::open(StoreSpec::slow_ssd(dir.path().join("s"), 0.2)).unwrap();
    let catalog = Catalog::new(store.clone(), 512);
    let spec = registry::by_name("rmat-40").unwrap().shrunk(11);
    let imgs = catalog.ensure(&spec).unwrap();
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let x = vec![1f32; imgs.num_verts];
    let (_, stats) = engine::spmv(&src, &x, &SpmmOpts::default()).unwrap();
    assert!(
        stats.read_gbps <= 0.25,
        "throttle violated: {:.3} GB/s",
        stats.read_gbps
    );
}
