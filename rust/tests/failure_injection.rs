//! Failure injection: corrupted images, truncated objects, missing
//! artifacts, bad requests — the coordinator must fail loudly and
//! cleanly, never hang, never return wrong numbers silently.

use sem_spmm::coordinator::Catalog;
use sem_spmm::format::delta::DeltaOp;
use sem_spmm::format::tiled::TiledImage;
use sem_spmm::format::{convert, Csr, TileFormat};
use sem_spmm::graph::{registry, rmat};
use sem_spmm::io::{BufferPool, DeltaConfig, DeltaStore, IoEngine, Manifest, ShardedStore, StoreSpec};
use sem_spmm::matrix::DenseMatrix;
use sem_spmm::spmm::{engine, DeltaSource, SemSource, Source, SpmmOpts};
use std::collections::BTreeMap;
use std::sync::Arc;

fn store(dir: &std::path::Path) -> Arc<ShardedStore> {
    ShardedStore::open(StoreSpec::unthrottled(dir)).unwrap()
}

fn sample_image(store: &Arc<ShardedStore>, name: &str) -> Csr {
    let el = rmat::generate(10, 8000, rmat::RmatParams::default(), 3);
    let m = Csr::from_edgelist(&el);
    let img = TiledImage::build(&m, 256, TileFormat::Scsr);
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put(name, &buf).unwrap();
    m
}

#[test]
fn corrupted_magic_is_rejected() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    sample_image(&s, "m.semm");
    // Flip the magic.
    let mut bytes = s.get("m.semm").unwrap();
    bytes[0] ^= 0xFF;
    s.put("m.semm", &bytes).unwrap();
    assert!(SemSource::open(&s, "m.semm").is_err());
}

#[test]
fn bad_version_is_rejected() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    sample_image(&s, "m.semm");
    let mut bytes = s.get("m.semm").unwrap();
    bytes[4] = 99; // version
    s.put("m.semm", &bytes).unwrap();
    assert!(SemSource::open(&s, "m.semm").is_err());
}

#[test]
fn truncated_data_area_errors_not_hangs() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let m = sample_image(&s, "m.semm");
    // Chop the tail off the data area: header/index parse fine, reads of
    // late tile rows must error.
    let bytes = s.get("m.semm").unwrap();
    s.put("m.semm", &bytes[..bytes.len() - (bytes.len() / 3)]).unwrap();
    let sem = SemSource::open(&s, "m.semm").unwrap();
    let x = DenseMatrix::random(m.ncols, 2, 1);
    let r = engine::spmm_out(
        &Source::Sem(sem),
        &x,
        &SpmmOpts {
            threads: 2,
            ..Default::default()
        },
    );
    assert!(r.is_err(), "truncated image must surface an I/O error");
}

#[test]
fn missing_object_errors() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    assert!(SemSource::open(&s, "absent.semm").is_err());
    assert!(s.open_file("absent").is_err());
}

#[test]
fn corrupted_csr_image_rejected_by_converter() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    s.put("bad.csr", &[7u8; 256]).unwrap();
    assert!(convert::convert(&s, "bad.csr", "out.semm", 256, TileFormat::Scsr).is_err());
}

#[test]
fn io_engine_survives_error_storm() {
    // A mix of valid and past-EOF reads: every ticket resolves, no hangs,
    // valid reads stay correct.
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let data = vec![5u8; 10_000];
    s.put("obj", &data).unwrap();
    let f = s.open_file("obj").unwrap();
    let eng = IoEngine::new(&s, 3, BufferPool::new(true, 16));
    let tickets: Vec<_> = (0..60)
        .map(|i| {
            if i % 3 == 0 {
                eng.submit(&f, 9_000, 5_000) // past EOF
            } else {
                eng.submit(&f, (i * 100) as u64, 100)
            }
        })
        .collect();
    let mut errs = 0;
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait(i % 2 == 0) {
            Ok(buf) => {
                assert!(buf.iter().all(|&b| b == 5));
                eng.recycle(buf);
            }
            Err(_) => errs += 1,
        }
    }
    assert_eq!(errs, 20);
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_missing_artifact_errors_cleanly() {
    let dir = sem_spmm::util::tempdir();
    let rt = sem_spmm::runtime::XlaRuntime::new(dir.path()).unwrap();
    assert!(!rt.has("nope"));
    assert!(rt.get("nope").is_err());
    assert!(rt.run1_f32("nope", &[]).is_err());
}

#[cfg(feature = "pjrt")]
#[test]
fn garbage_artifact_fails_to_parse() {
    let dir = sem_spmm::util::tempdir();
    std::fs::write(dir.path().join("junk.hlo.txt"), "this is not hlo").unwrap();
    let rt = sem_spmm::runtime::XlaRuntime::new(dir.path()).unwrap();
    assert!(rt.get("junk").is_err());
}

#[test]
fn native_backend_rejects_bad_shapes_cleanly() {
    // The always-available backend must error (not panic) on contract
    // violations, mirroring the artifact runtime's failure behaviour.
    let be = sem_spmm::runtime::default_backend();
    let x = DenseMatrix::random(100, 4, 1);
    let y = DenseMatrix::random(90, 4, 2);
    assert!(be.xty(&x, &y).is_err());
    let h = DenseMatrix::random(4, 50, 3);
    let wtw = DenseMatrix::random(3, 3, 4);
    assert!(be.nmf_update_h(&h, &h, &wtw).is_err());
    let w = DenseMatrix::random(50, 4, 5);
    let hht = DenseMatrix::random(5, 5, 6);
    assert!(be.nmf_update_w(&w, &w, &hht).is_err());
}

#[test]
fn native_backend_rejects_oversized_coo_tile() {
    let be = sem_spmm::runtime::default_backend();
    let too_tall = DenseMatrix::random(sem_spmm::runtime::COO_T + 1, 4, 7);
    assert!(be.coo_spmm_tile(&[0], &[0], &[1.0], &too_tall).is_err());
    // Mismatched index/value lengths are rejected too.
    let x = DenseMatrix::random(16, 4, 8);
    assert!(be.coo_spmm_tile(&[0, 1], &[0], &[1.0, 2.0], &x).is_err());
}

#[test]
fn service_rejects_malformed_requests_without_dying() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let catalog = Catalog::new(s, 256);
    let svc = sem_spmm::coordinator::service::Service::new(
        catalog,
        SpmmOpts {
            threads: 1,
            ..Default::default()
        },
    )
    .unwrap();
    for req in ["", "SPMM", "SPMM twitter notanumber", "PAGERANK x y z w"] {
        match svc.dispatch(req) {
            Ok(Some(j)) => assert!(j.get("error").is_some(), "req '{req}'"),
            Ok(None) => panic!("malformed '{req}' closed the connection"),
            Err(_) => {} // surfaced as error — also fine
        }
    }
    // Still serves valid requests afterwards.
    let r = svc.dispatch("PING").unwrap().unwrap();
    assert!(r.get("pong").is_some());
}

#[test]
fn zero_row_and_empty_matrices() {
    // Degenerate shapes must not panic anywhere in the pipeline.
    let m = Csr::from_sorted_pairs(0, 0, &[]);
    let img = TiledImage::build(&m, 64, TileFormat::Scsr);
    assert_eq!(img.meta.n_tile_rows(), 0);
    // A matrix with rows but no entries.
    let m = Csr::from_sorted_pairs(100, 100, &[]);
    let img = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
    let x = DenseMatrix::random(100, 2, 1);
    let (y, _) = engine::spmm_out(&Source::Mem(img), &x, &SpmmOpts::sequential()).unwrap();
    assert!(y.data.iter().all(|&v| v == 0.0));
}

/// A 4-shard store (optionally parity-protected) with a small stripe
/// plus an image big enough that every tile-row-group read spans
/// several shards.
fn sharded_store_with_image(
    dir: &std::path::Path,
    parity: bool,
) -> (Arc<ShardedStore>, Csr) {
    let s = ShardedStore::open(StoreSpec {
        dir: dir.to_path_buf(),
        shards: 4,
        stripe_bytes: 2048,
        read_gbps: None,
        write_gbps: None,
        latency_us: 0,
        parity,
    })
    .unwrap();
    let m = sample_image(&s, "m.semm");
    (s, m)
}

/// Chop one shard's backing file mid-object.
fn maim_shard(s: &Arc<ShardedStore>, shard: usize, name: &str) {
    let path = s.spec().shard_dir(shard).join(name);
    let len = std::fs::metadata(&path).unwrap().len();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&path)
        .unwrap()
        .set_len(len / 4)
        .unwrap();
}

#[test]
fn sem_run_errors_when_one_of_n_shards_fails_polling_and_blocking() {
    // A shard read error mid-SEM-run must propagate out of spmm() as an
    // Err — no hang — in both wait modes, even though 3 of 4 shards stay
    // perfectly healthy.
    for polling in [true, false] {
        let dir = sem_spmm::util::tempdir();
        let (s, m) = sharded_store_with_image(dir.path(), false);
        maim_shard(&s, 2, "m.semm");
        let sem = SemSource::open(&s, "m.semm").unwrap();
        let x = DenseMatrix::random(m.ncols, 2, 5);
        let r = engine::spmm_out(
            &Source::Sem(sem),
            &x,
            &SpmmOpts {
                threads: 2,
                io_polling: polling,
                ..Default::default()
            },
        );
        assert!(
            r.is_err(),
            "polling={polling}: one dead shard must fail the run"
        );
    }
}

#[test]
fn healthy_sharded_run_unaffected_by_failure_of_unused_object() {
    // Sanity inverse: maiming an unrelated object leaves the run intact.
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), false);
    let junk = vec![1u8; 40_000];
    s.put("other", &junk).unwrap();
    maim_shard(&s, 1, "other");
    let sem = SemSource::open(&s, "m.semm").unwrap();
    let x = DenseMatrix::random(m.ncols, 2, 6);
    let expect = m.spmm_ref(&x.data, 2);
    let (got, _) = engine::spmm_out(
        &Source::Sem(sem),
        &x,
        &SpmmOpts {
            threads: 2,
            ..Default::default()
        },
    )
    .unwrap();
    for (a, b) in got.data.iter().zip(&expect) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn mid_batch_shard_error_fails_every_rider_but_not_the_batcher() {
    // One dead shard of four mid-batch: every rider of that shared pass
    // gets an error reply (naming the failure), while the dispatcher
    // stays healthy — subsequent requests against an intact dataset on
    // the same store are served correctly. No poisoned state, no hang.
    use sem_spmm::coordinator::batcher::{BatchConfig, BatchJob, Batcher};
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), false);
    // A second, healthy image on the same sharded store.
    let m2 = sample_image(&s, "ok.semm");
    maim_shard(&s, 2, "m.semm");

    let batcher = Batcher::new(
        SpmmOpts {
            threads: 2,
            ..Default::default()
        },
        BatchConfig {
            max_riders: 4,
            max_linger: std::time::Duration::from_millis(40),
            ..BatchConfig::default()
        },
    )
    .unwrap();
    let src = Source::Sem(SemSource::open(&s, "m.semm").unwrap());
    let tickets: Vec<_> = (0..3u64)
        .map(|i| {
            let x = DenseMatrix::random(m.ncols, 2, 50 + i);
            batcher
                .submit("broken", &src, BatchJob::forward(x, format!("r{i}")))
                .unwrap()
        })
        .collect();
    let mut failures = 0;
    for t in tickets {
        match t.wait() {
            Ok(_) => {}
            Err(e) => {
                failures += 1;
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("batched pass"),
                    "error must name the shared pass: {msg}"
                );
            }
        }
    }
    assert_eq!(failures, 3, "every rider of the failed pass must error");

    // The batcher keeps serving: a healthy dataset works right after.
    let x = DenseMatrix::random(m2.ncols, 2, 9);
    let ok_src = Source::Sem(SemSource::open(&s, "ok.semm").unwrap());
    let r = batcher
        .run("ok", &ok_src, BatchJob::forward(x.clone(), "after"))
        .unwrap();
    let expect = m2.spmm_ref(&x.data, 2);
    for (a, b) in r.output.data.iter().zip(&expect) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn service_survives_a_corrupted_dataset_and_keeps_serving() {
    // Service-level continuity: a dataset whose tiled image rots on the
    // store yields error replies, but the connection loop and batcher
    // keep accepting and serving other datasets.
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let catalog = Catalog::new(s.clone(), 256);
    let svc = sem_spmm::coordinator::service::Service::new(
        catalog,
        SpmmOpts {
            threads: 1,
            ..Default::default()
        },
    )
    .unwrap();
    // Materialize the dataset, then corrupt its adjacency image.
    let info = svc.dispatch("INFO twitter").unwrap().unwrap();
    assert!(info.get("nnz").is_some());
    let spec = registry::by_name("twitter").unwrap().shrunk(12);
    let imgs = Catalog::new(s.clone(), 256).ensure(&spec).unwrap();
    s.put(&imgs.adj, &[0xAB; 128]).unwrap();
    // The corrupted dataset errors (open or sweep — either way, loudly).
    assert!(svc.dispatch("SPMV twitter").is_err());
    // ...but the service still answers, including batched work on an
    // intact dataset.
    let r = svc.dispatch("PING").unwrap().unwrap();
    assert!(r.get("pong").is_some());
    let r = svc.dispatch("SPMV rmat-40").unwrap().unwrap();
    let info = svc.dispatch("INFO rmat-40").unwrap().unwrap();
    assert_eq!(
        r.get("sum").unwrap().as_f64().unwrap(),
        info.get("nnz").unwrap().as_f64().unwrap()
    );
}

#[test]
fn parity_store_serves_riders_bit_identical_through_a_dead_shard() {
    // With `store.parity` on, killing one of four shards mid-service must
    // not fail anyone: every rider of the shared pass still succeeds, the
    // store reports reconstructed reads, and the outputs are bit-for-bit
    // what the healthy store produced.
    use sem_spmm::coordinator::batcher::{BatchConfig, BatchJob, Batcher};
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), true);
    let xs: Vec<DenseMatrix> = (0..3u64)
        .map(|i| DenseMatrix::random(m.ncols, 2, 70 + i))
        .collect();
    let batcher = Batcher::new(
        SpmmOpts {
            threads: 2,
            ..Default::default()
        },
        BatchConfig {
            max_riders: 4,
            max_linger: std::time::Duration::from_millis(40),
            ..BatchConfig::default()
        },
    )
    .unwrap();
    let run_all = |tag: &str| -> Vec<sem_spmm::coordinator::RideResult> {
        let src = Source::Sem(SemSource::open(&s, "m.semm").unwrap());
        let tickets: Vec<_> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| {
                batcher
                    .submit(
                        "k",
                        &src,
                        BatchJob::forward(x.clone(), format!("{tag}{i}")),
                    )
                    .unwrap()
            })
            .collect();
        tickets.into_iter().map(|t| t.wait().unwrap()).collect()
    };

    let healthy = run_all("h");
    assert_eq!(s.degraded.degraded_reads.get(), 0, "healthy run reconstructed");

    maim_shard(&s, 2, "m.semm");
    let degraded = run_all("d");
    assert!(
        s.degraded.degraded_reads.get() > 0,
        "dead shard never triggered reconstruction"
    );
    assert!(
        s.degraded.reconstructed_bytes.get() > 0,
        "reconstruction rebuilt no bytes"
    );
    let ride_degraded: u64 = degraded.iter().map(|r| r.stats.degraded_reads).sum();
    assert!(
        ride_degraded > 0,
        "per-ride stats must surface the degraded reads"
    );
    for (i, (d, h)) in degraded.iter().zip(&healthy).enumerate() {
        assert!(
            d.output.data == h.output.data,
            "rider {i}: degraded output diverged from the healthy run"
        );
    }
}

#[test]
fn slow_shard_times_out_into_reconstructed_reads_mid_pass() {
    // A shard whose token bucket is deep in the future (a stalling
    // device) is bypassed mid-SEM-pass once `store.degraded_timeout_ms`
    // is set: the pass finishes with correct numbers and the store
    // reports reconstructed reads instead of waiting out the backlog.
    let dir = sem_spmm::util::tempdir();
    let s = ShardedStore::open(StoreSpec {
        dir: dir.path().to_path_buf(),
        shards: 2,
        stripe_bytes: 256 << 10,
        read_gbps: Some(0.004), // 4 MB/s per shard
        write_gbps: None,
        latency_us: 0,
        parity: true,
    })
    .unwrap();
    let m = sample_image(&s, "m.semm");
    // A pad object whose first stripe lives entirely on shard 0: one big
    // read of it books ~64 ms of shard-0 bucket debt.
    s.put("pad", &vec![3u8; 512 << 10]).unwrap();
    let pad = s.open_file("pad").unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let bg = std::thread::spawn(move || {
        let mut buf = vec![0u8; 256 << 10];
        tx.send(()).unwrap();
        pad.read_at(0, &mut buf).unwrap();
    });
    rx.recv().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(10));
    s.set_degraded_read_timeout(Some(std::time::Duration::from_millis(2)));

    let sem = SemSource::open(&s, "m.semm").unwrap();
    let x = DenseMatrix::random(m.ncols, 2, 31);
    let (got, stats) = engine::spmm_out(
        &Source::Sem(sem),
        &x,
        &SpmmOpts {
            threads: 2,
            ..Default::default()
        },
    )
    .unwrap();
    s.set_degraded_read_timeout(None);
    bg.join().unwrap();
    assert!(
        stats.degraded_reads > 0,
        "backlogged shard was never bypassed into reconstruction"
    );
    let expect = m.spmm_ref(&x.data, 2);
    for (a, b) in got.data.iter().zip(&expect) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn narrow_tenant_boards_ahead_of_a_wide_flood_end_to_end() {
    // Starvation check over a real SEM source: a wide tenant saturates
    // the queue behind a blocker pass; the narrow tenant's lone SPMV-
    // sized job must board long before the whale's tail. `pass_seq` is
    // assigned at dispatch, so it is the boarding order.
    use sem_spmm::coordinator::batcher::{BatchConfig, BatchHook, BatchJob, Batcher, Ticket};
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), false);
    let b = Batcher::new(
        SpmmOpts {
            threads: 2,
            ..Default::default()
        },
        BatchConfig {
            max_riders: 1, // one seat per pass: pick order is visible
            max_linger: std::time::Duration::ZERO,
            max_inflight: 1,
            tenant_weights: vec![("minnow".into(), 2.0)],
            ..BatchConfig::default()
        },
    )
    .unwrap();
    let src = Source::Sem(SemSource::open(&s, "m.semm").unwrap());
    let x1 = DenseMatrix::random(m.ncols, 1, 5);
    // Blocker: holds the single in-flight slot while the flood queues.
    let gate: BatchHook = Box::new(|_, _, _| {
        std::thread::sleep(std::time::Duration::from_millis(150));
    });
    let tb = b
        .submit(
            "k",
            &src,
            BatchJob::with_hook(x1.clone(), "gate", 1, gate).for_tenant("gate"),
        )
        .unwrap();
    let whale_tickets: Vec<Ticket> = (0..6u64)
        .map(|i| {
            b.submit(
                "k",
                &src,
                BatchJob::forward(DenseMatrix::random(m.ncols, 4, 80 + i), format!("w{i}"))
                    .for_tenant("whale"),
            )
            .unwrap()
        })
        .collect();
    let tn = b
        .submit(
            "k",
            &src,
            BatchJob::forward(x1, "narrow").for_tenant("minnow"),
        )
        .unwrap();
    let narrow = tn.wait().unwrap();
    let whale_seqs: Vec<u64> = whale_tickets
        .into_iter()
        .map(|t| t.wait().unwrap().stats.pass_seq)
        .collect();
    tb.wait().unwrap();
    let later_whales = whale_seqs
        .iter()
        .filter(|&&q| q > narrow.stats.pass_seq)
        .count();
    assert!(
        later_whales >= 4,
        "narrow rider (seq {}) starved behind the whale flood (seqs {whale_seqs:?})",
        narrow.stats.pass_seq
    );
}

#[test]
fn catalog_recovers_from_partially_deleted_dataset() {
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let catalog = Catalog::new(s.clone(), 256);
    let spec = registry::by_name("twitter").unwrap().shrunk(9);
    let imgs = catalog.ensure(&spec).unwrap();
    // Delete one object; ensure() must rebuild the set.
    s.remove(&imgs.adj).unwrap();
    let imgs2 = catalog.ensure(&spec).unwrap();
    assert_eq!(imgs2.nnz, imgs.nnz);
    assert!(s.exists(&imgs2.adj));
}

// ---------------------------------------------------------------------------
// Delta layer under failure: aborted compactions, crash debris, dead shards.
// The committed version must stay readable bit-identical through all of it,
// and retries must GC the wreckage instead of tripping over it.
// ---------------------------------------------------------------------------

/// Compaction triggers disabled so the tests place every state
/// transition by hand.
fn manual_delta_cfg() -> DeltaConfig {
    DeltaConfig {
        buffer_bytes: 64 << 20,
        compact_runs: usize::MAX,
        major_compact_ratio: f64::INFINITY,
    }
}

/// Reference edge map of a binary CSR (every present edge weighs 1.0,
/// matching what `for_each_edge` yields for Binary images).
fn csr_edge_map(m: &Csr) -> BTreeMap<(u32, u32), f32> {
    let mut map = BTreeMap::new();
    for r in 0..m.nrows {
        for k in m.indptr[r] as usize..m.indptr[r + 1] as usize {
            map.insert((r as u32, m.indices[k]), 1.0);
        }
    }
    map
}

/// The merged (base ⊕ live runs) edge map as the streaming engine sees
/// it — opened fresh so it always reflects the on-store manifest.
fn merged_edge_map(s: &Arc<ShardedStore>, name: &str) -> BTreeMap<(u32, u32), f32> {
    let src = Source::Delta(DeltaSource::open(s, name).unwrap());
    let mut map = BTreeMap::new();
    src.for_each_edge(|r, c, v| {
        assert!(map.insert((r, c), v).is_none(), "edge ({r},{c}) emitted twice");
    })
    .unwrap();
    map
}

/// Base image + two committed delta runs (an insert and a delete of a
/// real base edge), plus the model the merged view must equal.
fn delta_with_two_runs(
    s: &Arc<ShardedStore>,
    m: &Csr,
) -> (DeltaStore, BTreeMap<(u32, u32), f32>) {
    let ds = DeltaStore::open(s, "m.semm", manual_delta_cfg()).unwrap();
    let mut model = csr_edge_map(m);
    let &victim = model.keys().next().unwrap();
    ds.stage(DeltaOp::upsert(3, 999, 1.0)).unwrap();
    model.insert((3, 999), 1.0);
    ds.commit().unwrap();
    ds.stage(DeltaOp::delete(victim.0, victim.1)).unwrap();
    model.remove(&victim);
    ds.commit().unwrap();
    (ds, model)
}

#[test]
fn aborted_major_compaction_leaves_previous_version_readable_and_retry_gcs_debris() {
    // A crash (or shard failure) mid-major-compaction dies BEFORE the
    // manifest swap, leaving a partial new base and a partial run on the
    // store. The committed version must keep reading back bit-identical,
    // and a retried compaction must GC the debris and succeed.
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), false);
    let (ds, model) = delta_with_two_runs(&s, &m);
    let man_before = ds.manifest().unwrap();
    assert_eq!(man_before.runs.len(), 2);
    assert_eq!(merged_edge_map(&s, "m.semm"), model);

    // Crash debris: garbage where the next base version and the next run
    // would land, with the manifest untouched (the swap never happened).
    s.put(&Manifest::base_object("m.semm", 1), &vec![0xCD; 4096]).unwrap();
    s.put(&Manifest::run_object("m.semm", man_before.next_seq), &[0xAB; 37]).unwrap();

    // No torn swap: the manifest and the merged view are unchanged.
    assert_eq!(ds.manifest().unwrap(), man_before);
    assert_eq!(merged_edge_map(&s, "m.semm"), model);

    // Retry compacts through: debris GC'd, version stepped, same edges.
    assert!(ds.major_compact().unwrap());
    let man = ds.manifest().unwrap();
    assert_eq!(man.base_version, 1);
    assert!(man.runs.is_empty());
    assert_eq!(man.base, Manifest::base_object("m.semm", 1));
    assert!(
        !s.exists(&Manifest::run_object("m.semm", man_before.next_seq)),
        "partial run from the aborted attempt must be GC'd"
    );
    for &seq in &man_before.runs {
        assert!(
            !s.exists(&Manifest::run_object("m.semm", seq)),
            "folded run {seq} must be removed after the swap"
        );
    }
    assert_eq!(merged_edge_map(&s, "m.semm"), model);
    // The swapped base is a healthy canonical image on its own.
    SemSource::open(&s, &man.base).unwrap();
}

#[test]
fn commit_replaces_an_aborted_partial_run_flush() {
    // A commit that died after writing part of its run object but before
    // publishing it in the manifest: the orphan must be invisible, and
    // the NEXT commit must GC it and reuse the sequence number cleanly.
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), false);
    let (ds, mut model) = delta_with_two_runs(&s, &m);
    let next = ds.manifest().unwrap().next_seq;
    s.put(&Manifest::run_object("m.semm", next), &[0x5A; 21]).unwrap();
    assert_eq!(merged_edge_map(&s, "m.semm"), model, "orphan run must stay invisible");

    ds.stage(DeltaOp::upsert(7, 7, 1.0)).unwrap();
    model.insert((7, 7), 1.0);
    let rep = ds.commit().unwrap();
    assert_eq!(rep.seq, Some(next), "retried flush reuses the unpublished seq");
    assert_eq!(merged_edge_map(&s, "m.semm"), model);
}

#[test]
fn major_compaction_completes_through_a_dead_shard_on_a_parity_store() {
    // One of four shards dies under the BASE image mid-lifecycle on a
    // parity store: the merged view keeps serving via reconstruction,
    // and a major compaction — which streams every base tile row — still
    // completes and produces a healthy new base with the same edges.
    let dir = sem_spmm::util::tempdir();
    let (s, m) = sharded_store_with_image(dir.path(), true);
    let (ds, model) = delta_with_two_runs(&s, &m);
    maim_shard(&s, 2, "m.semm");

    assert_eq!(merged_edge_map(&s, "m.semm"), model);
    assert!(
        s.degraded.degraded_reads.get() > 0,
        "dead shard never triggered reconstruction"
    );

    assert!(ds.major_compact().unwrap());
    let man = ds.manifest().unwrap();
    assert_eq!(man.base_version, 1);
    assert_eq!(merged_edge_map(&s, "m.semm"), model);
    SemSource::open(&s, &man.base).unwrap();
}

#[test]
fn service_keeps_answering_on_the_committed_version_through_refresh_debris() {
    // Service-level continuity: debris from an in-flight (or crashed)
    // refresh on the store must not change what SPMV serves — reads pin
    // to the committed manifest version — and the next COMMIT quietly
    // GCs the wreckage.
    let dir = sem_spmm::util::tempdir();
    let s = store(dir.path());
    let catalog = Catalog::new(s.clone(), 256);
    let svc = sem_spmm::coordinator::service::Service::new(
        catalog,
        SpmmOpts {
            threads: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let sum = |svc: &sem_spmm::coordinator::service::Service| -> f64 {
        svc.dispatch("SPMV rmat-40")
            .unwrap()
            .unwrap()
            .get("sum")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let sum0 = sum(&svc);
    svc.dispatch("UPDATE rmat-40 add 7 4090").unwrap().unwrap();
    let r = svc.dispatch("COMMIT rmat-40").unwrap().unwrap();
    assert_eq!(r.get("committed_ops").unwrap().as_f64().unwrap(), 1.0);
    let sum_add = sum(&svc);
    // +1 if the edge was new, unchanged if the random base had it.
    assert!(sum_add == sum0 || sum_add == sum0 + 1.0);

    // Debris where a refresh would write, manifest untouched.
    let spec = registry::by_name("rmat-40").unwrap().shrunk(12);
    let imgs = Catalog::new(s.clone(), 256).ensure(&spec).unwrap();
    let next = Manifest::load(&s, &imgs.adj).unwrap().next_seq;
    s.put(&Manifest::base_object(&imgs.adj, 1), &vec![0xEE; 2048]).unwrap();
    s.put(&Manifest::run_object(&imgs.adj, next), &[0x11; 9]).unwrap();
    assert_eq!(
        sum(&svc),
        sum_add,
        "debris must not leak into served results"
    );

    // Deleting the edge guaranteed present after the add moves the sum
    // by exactly -1.0 (the adjacency image is binary), and the commit
    // GCs the debris. (`sum0` itself is not re-asserted: the random
    // base could have contained the edge already.)
    svc.dispatch("UPDATE rmat-40 del 7 4090").unwrap().unwrap();
    svc.dispatch("COMMIT rmat-40").unwrap().unwrap();
    assert_eq!(sum(&svc), sum_add - 1.0);
    assert!(!s.exists(&Manifest::base_object(&imgs.adj, 1)));
    assert!(!s.exists(&Manifest::run_object(&imgs.adj, next)));
    let r = svc.dispatch("PING").unwrap().unwrap();
    assert!(r.get("pong").is_some());
}
