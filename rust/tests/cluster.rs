//! Cross-partition battery for the partitioned scale-out mode
//! (`coordinator/cluster.rs`): the cluster control plane must be a
//! *refactoring* of the single-node engine, not a reimplementation.
//!
//! * **Differential**: at 1, 2 and 4 nodes, over striped per-node
//!   stores, with both partitioners, a fused forward + transpose pass
//!   must reproduce the single-node engine — bit-identical everywhere
//!   except the documented Arith-transpose-at-many-nodes case (the f32
//!   ⊕-fold tree follows node boundaries there, exactly as it follows
//!   worker boundaries on one node). All four semirings, weighted RMAT
//!   and binary SBM. `nodes = 1` is additionally stats-for-stats.
//! * **PageRank**: rides entirely on forward passes, so the partitioned
//!   run is bit-identical to the single-node fused path at every node
//!   count.
//! * **Properties**: every stored nonzero lands on exactly one node and
//!   the concatenated partitions reconstruct the image byte-for-byte;
//!   the balanced splitter never loses to equal-rows on a power-law
//!   graph; metered channel bytes equal the analytic panel-exchange
//!   volume computed independently from the CSR.
//! * **Failure injection**: a dead shard inside one node's parity store
//!   degrades to reconstructed reads without changing a bit; a killed
//!   node fails the pass with a structured [`NodeDown`] naming it, and
//!   the cluster serves the next request after `revive`.

use sem_spmm::apps::pagerank::{self, PageRankConfig};
use sem_spmm::coordinator::cluster::{
    nnz_imbalance, partition_image, plan_ranges, tile_row_weights, PART_OBJ,
};
use sem_spmm::coordinator::{Cluster, ClusterConfig, ClusterOp, NodeDown, Partitioner};
use sem_spmm::format::tiled::{decode_all, TiledImage};
use sem_spmm::format::{Csr, TileFormat};
use sem_spmm::graph::{rmat, sbm};
use sem_spmm::io::{ShardedStore, StoreSpec};
use sem_spmm::matrix::{DenseMatrix, NumaDense};
use sem_spmm::spmm::{
    engine, run_pass_ring, Arith, MinPlus, MinSelect, OrAnd, OutputSink, SemSource, Semiring,
    Source, SpmmOpts, StreamPass,
};
use std::path::Path;

const TILE: usize = 128;

fn rmat_weighted() -> Csr {
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 0xC1A5);
    let mut m = Csr::from_edgelist(&el);
    let mut rng = sem_spmm::util::Xoshiro256::new(0x17);
    m.vals = Some((0..m.nnz()).map(|_| rng.next_f32() * 2.0 - 1.0).collect());
    m
}

fn sbm_binary() -> Csr {
    Csr::from_edgelist(&sbm::generate(
        sbm::SbmParams {
            num_verts: 1 << 10,
            num_edges: 14_000,
            num_clusters: 16,
            in_out: 8.0,
            clustered_order: true,
        },
        0x5B31,
    ))
}

/// 4-shard striped spec rooted at `dir` — node stores inherit it under
/// `dir/node-k/`, so every node really stripes its slice.
fn striped(dir: &Path, parity: bool) -> StoreSpec {
    StoreSpec {
        dir: dir.to_path_buf(),
        shards: 4,
        stripe_bytes: 2048,
        read_gbps: None,
        write_gbps: None,
        latency_us: 0,
        parity,
    }
}

/// Deterministic engine options: static partitioning so the worker
/// ⊕-fold segmentation (and hence Arith-transpose bits and f64 hook
/// accumulators) is identical run-to-run.
fn det_opts() -> SpmmOpts {
    SpmmOpts {
        threads: 3,
        io_workers: 2,
        load_balance: false,
        ..Default::default()
    }
}

fn assert_bits(tag: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{tag}: index {i}: {a} vs {b} (bits differ)"
        );
    }
}

fn assert_close(tag: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * b.abs().max(1.0),
            "{tag}: index {i}: {a} vs {b}"
        );
    }
}

/// The differential core: one graph, one semiring. The single-node
/// engine (over its own striped SEM store) sets the reference bits for
/// a fused forward + transpose pass; every (nodes, partitioner) cluster
/// must reproduce them per the contract in the cluster module docs.
fn cluster_vs_engine<S: Semiring>(gname: &str, m: &Csr) {
    let img = TiledImage::build(m, TILE, TileFormat::Scsr);
    let p = 4;
    let x = DenseMatrix::random(m.ncols, p, 0xA1);
    let y = DenseMatrix::random(m.nrows, p, 0xB2);
    let opts = det_opts();
    let dir = sem_spmm::util::tempdir();

    // Reference: the single-node engine streaming the whole image from
    // an identically-shaped striped store.
    let rstore = ShardedStore::open(striped(&dir.path().join("ref"), false)).unwrap();
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    rstore.put("a.semm", &buf).unwrap();
    let src = Source::Sem(SemSource::open(&rstore, "a.semm").unwrap());
    let ncfg = engine::numa_config(TILE, m.nrows.max(m.ncols), &opts);
    let xs = NumaDense::from_dense(&x, ncfg);
    let ys = NumaDense::from_dense(&y, ncfg);
    let fwd = NumaDense::zeros(m.nrows, p, ncfg);
    let tr = NumaDense::zeros(m.ncols, p, ncfg);
    let ref_stats = {
        let pass = StreamPass::<S>::new()
            .forward(&xs, OutputSink::Mem(&fwd))
            .transpose(&ys, &tr);
        run_pass_ring::<S>(&src, &pass, &opts).unwrap().stats
    };
    assert!(ref_stats.bytes_read > 0, "{gname}: reference must stream");
    let want_fwd = fwd.to_dense().data;
    let want_tr = tr.to_dense().data;

    for nodes in [1usize, 2, 4] {
        for pt in [Partitioner::BalancedNnz, Partitioner::EqualRows] {
            let tag = format!("{gname}/{}/n{nodes}/{}", S::NAME, pt.name());
            let base = striped(&dir.path().join(format!("n{nodes}-{}", pt.name())), false);
            let ccfg = ClusterConfig {
                nodes,
                partitioner: pt,
                ..ClusterConfig::ec2(nodes)
            };
            let cluster = Cluster::build(&img, &base, &ccfg).unwrap();
            let r = cluster
                .run_pass::<S>(&[ClusterOp::Forward(&x), ClusterOp::Transpose(&y)], &opts)
                .unwrap();
            // Every node streamed its slice from its own store.
            for n in &r.stats.per_node {
                assert!(n.spmm.bytes_read > 0, "{tag}: node {} never streamed", n.node);
            }
            // Forward: bit-identical at every node count, in every ring.
            assert_bits(&format!("{tag}: forward"), &r.outputs[0].data, &want_fwd);
            // Transpose: bit-identical except Arith at nodes > 1, where
            // the ⊕-fold tree legitimately regroups across nodes.
            if !S::IS_ARITH || nodes == 1 {
                assert_bits(&format!("{tag}: transpose"), &r.outputs[1].data, &want_tr);
            } else {
                assert_close(&format!("{tag}: transpose"), &r.outputs[1].data, &want_tr);
            }

            // nodes = 1 is the engine run: same deterministic task/byte/
            // cache/kernel statistics, not just the same numbers.
            if nodes == 1 {
                assert!(
                    r.stats.per_node[0].spmm.matches_deterministic(&ref_stats),
                    "{tag}: single-node cluster stats diverged from the engine:\n{:?}\nvs\n{:?}",
                    r.stats.per_node[0].spmm,
                    ref_stats
                );
            }

            // The fused pass equals separate single-op passes bit for
            // bit — same partition, same static schedule, same folds.
            if nodes == 2 && pt == Partitioner::BalancedNnz {
                let f = cluster
                    .run_pass::<S>(&[ClusterOp::Forward(&x)], &opts)
                    .unwrap();
                let t2 = cluster
                    .run_pass::<S>(&[ClusterOp::Transpose(&y)], &opts)
                    .unwrap();
                assert_bits(
                    &format!("{tag}: forward-only vs fused"),
                    &f.outputs[0].data,
                    &r.outputs[0].data,
                );
                assert_bits(
                    &format!("{tag}: transpose-only vs fused"),
                    &t2.outputs[0].data,
                    &r.outputs[1].data,
                );
            }
        }
    }
}

#[test]
fn partitioned_rmat_weighted_matches_single_node_all_rings() {
    let m = rmat_weighted();
    cluster_vs_engine::<Arith>("rmat-w", &m);
    cluster_vs_engine::<MinPlus>("rmat-w", &m);
    cluster_vs_engine::<OrAnd>("rmat-w", &m);
    cluster_vs_engine::<MinSelect>("rmat-w", &m);
}

#[test]
fn partitioned_sbm_binary_matches_single_node_all_rings() {
    let m = sbm_binary();
    cluster_vs_engine::<Arith>("sbm-b", &m);
    cluster_vs_engine::<MinPlus>("sbm-b", &m);
    cluster_vs_engine::<OrAnd>("sbm-b", &m);
    cluster_vs_engine::<MinSelect>("sbm-b", &m);
}

/// Partitioned PageRank vs the single-node fused path: PageRank rides
/// entirely on forward passes, so it is bit-identical at every node
/// count — including the per-iteration residual/mass telemetry at
/// `nodes = 1`, where the cluster is the engine run.
#[test]
fn partitioned_pagerank_bit_identical_to_single_node_fused() {
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 0x9A17);
    let deg = el.col_degrees();
    let m = Csr::from_edgelist(&el);
    let img = TiledImage::build(&m, TILE, TileFormat::Scsr);
    let dir = sem_spmm::util::tempdir();

    let store = ShardedStore::open(striped(&dir.path().join("ref"), false)).unwrap();
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("g.semm", &buf).unwrap();
    let src = Source::Sem(SemSource::open(&store, "g.semm").unwrap());
    let cfg = PageRankConfig {
        iterations: 8,
        spmm: det_opts(),
        ..Default::default()
    };
    let (want, want_st) = pagerank::pagerank(&src, &deg, &store, &cfg).unwrap();

    for nodes in [1usize, 2, 4] {
        let base = striped(&dir.path().join(format!("n{nodes}")), false);
        let cluster = Cluster::build(&img, &base, &ClusterConfig::ec2(nodes)).unwrap();
        let (pr, st) = cluster.pagerank(&deg, &cfg).unwrap();
        assert_bits(&format!("pagerank n{nodes}"), &pr, &want);
        assert_eq!(st.iters, want_st.iters, "n{nodes}: iteration count");
        assert!(!st.converged, "tol = 0 must run all iterations");
        // Residual/mass: exact at nodes = 1 (same worker fold), within
        // f64 noise when node boundaries regroup the sums.
        for (i, (r, w)) in st.residuals.iter().zip(&want_st.residuals).enumerate() {
            if nodes == 1 {
                assert_eq!(r, w, "n1: residual iter {i}");
            } else {
                assert!((r - w).abs() < 1e-9, "n{nodes}: residual iter {i}: {r} vs {w}");
            }
        }
        for (i, (a, w)) in st.mass.iter().zip(&want_st.mass).enumerate() {
            assert!((a - w).abs() < 1e-9, "n{nodes}: mass iter {i}: {a} vs {w}");
        }
        // x̂ panels crossed the network every iteration, both ways.
        assert!(st.bytes_sent > 0 && st.bytes_received > 0);
    }
}

/// Property: under both partitioners and several node counts, every
/// stored nonzero lands on exactly one node, and the concatenated
/// partitions reconstruct the original image — coordinates, values,
/// per-node nnz totals, and the tile byte stream itself.
#[test]
fn every_nonzero_lands_on_exactly_one_node_and_partitions_reconstruct() {
    let m = rmat_weighted();
    let img = TiledImage::build(&m, TILE, TileFormat::Scsr);
    let (want_coords, want_vals) = decode_all(&img);
    let w = tile_row_weights(&img);
    assert_eq!(w.iter().sum::<u64>(), img.meta.nnz, "weights must cover all nnz");

    for pt in [Partitioner::BalancedNnz, Partitioner::EqualRows] {
        for nodes in [2usize, 4, 7] {
            let ranges = plan_ranges(&w, nodes, pt);
            assert_eq!(ranges.len(), nodes);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[nodes - 1].1, img.meta.n_tile_rows());
            let (mut coords, mut vals) = (Vec::new(), Vec::new());
            let mut data = Vec::new();
            let mut total_nnz = 0u64;
            for (k, &(lo, hi)) in ranges.iter().enumerate() {
                if k > 0 {
                    assert_eq!(lo, ranges[k - 1].1, "ranges must abut");
                }
                assert!(lo < hi, "node {k} got an empty range");
                let sub = partition_image(&img, lo, hi);
                total_nnz += sub.meta.nnz;
                data.extend_from_slice(&sub.data);
                let row_off = (lo * TILE) as u32;
                let (c, v) = decode_all(&sub);
                coords.extend(c.into_iter().map(|(r, cc)| (r + row_off, cc)));
                vals.extend(v);
            }
            let tag = format!("{}/n{nodes}", pt.name());
            assert_eq!(total_nnz, img.meta.nnz, "{tag}: nnz not partitioned exactly");
            assert_eq!(coords, want_coords, "{tag}: nonzeros lost, duplicated or moved");
            assert_eq!(vals, want_vals, "{tag}: values changed in transit");
            assert_eq!(data, img.data, "{tag}: tile bytes not sliced verbatim");
        }
    }
}

/// Property: on a power-law graph the balanced splitter's max-node-nnz
/// never exceeds equal-rows', and is strictly better somewhere.
#[test]
fn balanced_splitter_beats_equal_rows_on_power_law() {
    let m = Csr::from_edgelist(&rmat::generate(11, 40_000, rmat::RmatParams::default(), 0x77));
    let img = TiledImage::build(&m, 64, TileFormat::Scsr);
    let w = tile_row_weights(&img);
    let mut strictly_better = false;
    for nodes in [2usize, 4, 8] {
        let bal = nnz_imbalance(&w, &plan_ranges(&w, nodes, Partitioner::BalancedNnz));
        let eq = nnz_imbalance(&w, &plan_ranges(&w, nodes, Partitioner::EqualRows));
        assert!(
            bal <= eq + 1e-12,
            "nodes={nodes}: balanced {bal} worse than equal-rows {eq}"
        );
        strictly_better |= bal < eq - 1e-12;
    }
    assert!(
        strictly_better,
        "balanced splitter never improved on equal rows for a power-law graph"
    );
}

/// Property: metered channel bytes equal the analytic panel-exchange
/// volume, computed independently from the CSR — per node, per
/// direction, and cumulatively across passes. Forward ships only each
/// node's support rows in and its owned rows back; transpose the
/// reverse.
#[test]
fn metered_channel_bytes_equal_analytic_panel_volume() {
    let m = rmat_weighted();
    let img = TiledImage::build(&m, TILE, TileFormat::Scsr);
    let p = 3;
    let x = DenseMatrix::random(m.ncols, p, 0xE1);
    let y = DenseMatrix::random(m.nrows, p, 0xE2);
    let opts = det_opts();
    let dir = sem_spmm::util::tempdir();
    let weights = tile_row_weights(&img);

    for nodes in [2usize, 4] {
        let base = striped(&dir.path().join(format!("n{nodes}")), false);
        let cluster = Cluster::build(&img, &base, &ClusterConfig::ec2(nodes)).unwrap();
        let r = cluster
            .run_pass::<Arith>(&[ClusterOp::Forward(&x), ClusterOp::Transpose(&y)], &opts)
            .unwrap();

        let ranges = plan_ranges(&weights, nodes, Partitioner::BalancedNnz);
        let (mut want_sent, mut want_recvd) = (0u64, 0u64);
        for (k, &(tr_lo, tr_hi)) in ranges.iter().enumerate() {
            // Independent support computation straight from the CSR.
            let row_lo = tr_lo * TILE;
            let row_hi = (tr_hi * TILE).min(m.nrows);
            let mut support = vec![false; m.ncols.div_ceil(TILE)];
            for row in row_lo..row_hi {
                for e in m.indptr[row] as usize..m.indptr[row + 1] as usize {
                    support[m.indices[e] as usize / TILE] = true;
                }
            }
            let support_rows: usize = support
                .iter()
                .enumerate()
                .filter(|(_, s)| **s)
                .map(|(j, _)| ((j + 1) * TILE).min(m.ncols) - j * TILE)
                .sum();
            let rows = row_hi - row_lo;
            let part = &cluster.nodes[k].part;
            assert_eq!((part.row_lo, part.row_hi), (row_lo, row_hi), "n{nodes}/node {k}: rows");
            assert_eq!(part.support_rows, support_rows, "n{nodes}/node {k}: support");

            let want_in = ((support_rows + rows) * p * 4) as u64;
            let want_out = ((rows + support_rows) * p * 4) as u64;
            let ns = &r.stats.per_node[k];
            assert_eq!(ns.bytes_in, want_in, "n{nodes}/node {k}: bytes in");
            assert_eq!(ns.bytes_out, want_out, "n{nodes}/node {k}: bytes out");
            // 2 ops in + 2 ops back = 4 metered messages on the link.
            let model = cluster.link_secs(want_in + want_out, 4);
            assert!(
                (ns.comm_secs - model).abs() < 1e-12,
                "n{nodes}/node {k}: comm model {} vs {}",
                ns.comm_secs,
                model
            );
            want_sent += want_in;
            want_recvd += want_out;
        }
        assert_eq!(r.stats.bytes_sent, want_sent, "n{nodes}: total sent");
        assert_eq!(r.stats.bytes_received, want_recvd, "n{nodes}: total received");

        // Cumulative meters: a second identical pass doubles the totals.
        cluster
            .run_pass::<Arith>(&[ClusterOp::Forward(&x), ClusterOp::Transpose(&y)], &opts)
            .unwrap();
        assert_eq!(cluster.net_totals(), (2 * want_sent, 2 * want_recvd));
    }
}

/// Failure injection: chop one shard of one node's parity-striped store
/// mid-object. That node's sweeps degrade to reconstructed reads — the
/// pass still succeeds and the output does not change by a bit; the
/// other nodes stay clean.
#[test]
fn dead_shard_inside_one_node_degrades_to_reconstructed_reads() {
    let m = rmat_weighted();
    let img = TiledImage::build(&m, TILE, TileFormat::Scsr);
    let x = DenseMatrix::random(m.ncols, 4, 0xF1);
    let opts = det_opts();
    let dir = sem_spmm::util::tempdir();
    let cluster = Cluster::build(
        &img,
        &striped(dir.path(), true),
        &ClusterConfig::ec2(3),
    )
    .unwrap();

    let (healthy, hstats) = cluster.spmm(&x, &opts).unwrap();
    for n in &hstats.per_node {
        assert_eq!(n.spmm.degraded_reads, 0, "healthy run reconstructed on node {}", n.node);
    }

    // Chop shard 2 of node 1's store to a quarter of its length.
    let victim = &cluster.nodes[1].store;
    let path = victim.spec().shard_dir(2).join(PART_OBJ);
    let len = std::fs::metadata(&path).unwrap().len();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&path)
        .unwrap()
        .set_len(len / 4)
        .unwrap();

    let (degraded, dstats) = cluster.spmm(&x, &opts).unwrap();
    assert_bits("degraded vs healthy", &degraded.data, &healthy.data);
    assert!(
        dstats.per_node[1].spmm.degraded_reads > 0,
        "dead shard never triggered reconstruction"
    );
    assert!(victim.degraded.reconstructed_bytes.get() > 0);
    for k in [0usize, 2] {
        assert_eq!(
            dstats.per_node[k].spmm.degraded_reads, 0,
            "healthy node {k} reported degraded reads"
        );
    }
}

/// Failure injection: a killed node fails the pass with a structured
/// error naming it — repeatedly, without corrupting state — and after
/// `revive` the cluster serves the next request bit-identically.
#[test]
fn killed_node_yields_structured_error_and_cluster_recovers_on_revive() {
    let el = rmat::generate(10, 12_000, rmat::RmatParams::default(), 0x4B1D);
    let deg = el.col_degrees();
    let m = Csr::from_edgelist(&el);
    let img = TiledImage::build(&m, TILE, TileFormat::Scsr);
    let x = DenseMatrix::random(m.ncols, 2, 0xAB);
    let opts = det_opts();
    let dir = sem_spmm::util::tempdir();
    let cluster = Cluster::build(&img, &striped(dir.path(), false), &ClusterConfig::ec2(3)).unwrap();

    let (want, _) = cluster.spmm(&x, &opts).unwrap();

    cluster.kill(1);
    assert!(cluster.is_killed(1));
    let err = cluster.spmm(&x, &opts).unwrap_err();
    assert_eq!(
        err.downcast_ref::<NodeDown>(),
        Some(&NodeDown { node: 1 }),
        "error must be a structured NodeDown"
    );
    assert!(err.to_string().contains("node 1"), "error must name the node: {err}");
    // Every entry point refuses while the node is down, and keeps
    // refusing on retry — no half-run state accumulates.
    let err2 = cluster.spmv(&vec![1.0; m.ncols], &opts).unwrap_err();
    assert_eq!(err2.downcast_ref::<NodeDown>(), Some(&NodeDown { node: 1 }));
    let cfg = PageRankConfig {
        iterations: 2,
        spmm: det_opts(),
        ..Default::default()
    };
    let err3 = cluster.pagerank(&deg, &cfg).unwrap_err();
    assert_eq!(err3.downcast_ref::<NodeDown>(), Some(&NodeDown { node: 1 }));

    cluster.revive(1);
    assert!(!cluster.is_killed(1));
    let (again, _) = cluster.spmm(&x, &opts).unwrap();
    assert_bits("post-revive vs pre-kill", &again.data, &want.data);
    let (_, prst) = cluster.pagerank(&deg, &cfg).unwrap();
    assert_eq!(prst.iters, 2, "revived cluster must serve apps too");
}
