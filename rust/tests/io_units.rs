//! Satellite unit tests for `io/`: write-merging boundary behaviour of
//! [`MergedWriter`], [`BufferPool`] reuse under thread contention, the
//! `StoreSpec::slow_ssd` throttle actually bounding observed throughput,
//! and the sharded store scaling SEM read throughput with device count.

use sem_spmm::format::tiled::TiledImage;
use sem_spmm::format::{Csr, TileFormat};
use sem_spmm::graph::rmat;
use sem_spmm::io::{BufferPool, MergedWriter, ShardedStore, StoreSpec};
use sem_spmm::matrix::DenseMatrix;
use sem_spmm::spmm::{engine, SemSource, Source, SpmmOpts};
use std::sync::Arc;
use std::time::Instant;

fn unthrottled(dir: &std::path::Path) -> Arc<ShardedStore> {
    ShardedStore::open(StoreSpec::unthrottled(dir)).unwrap()
}

#[test]
fn merged_writer_merges_across_window_boundary_only_within_batches() {
    // Extents 0..100, 100..200 arrive in the first window, 200..300 in
    // the second: the writer must issue exactly one write per flushed
    // batch, and the final bytes must be the in-order concatenation.
    let dir = sem_spmm::util::tempdir();
    let store = unthrottled(dir.path());
    let f = store.create_file("out").unwrap();
    let w = MergedWriter::new(f, 200); // window = 200 bytes
    w.write(100, vec![2u8; 100]);
    w.write(0, vec![1u8; 100]); // hits the window → flush of [0,200)
    w.flush();
    w.write(200, vec![3u8; 100]);
    let report = w.finish().unwrap();
    assert_eq!(report.extents_in, 3);
    assert_eq!(report.bytes, 300);
    assert_eq!(report.writes_out, 2, "one merged write per batch");
    let got = store.get("out").unwrap();
    assert_eq!(&got[0..100], &[1u8; 100][..]);
    assert_eq!(&got[100..200], &[2u8; 100][..]);
    assert_eq!(&got[200..300], &[3u8; 100][..]);
}

#[test]
fn merged_writer_zero_length_and_touching_extents() {
    let dir = sem_spmm::util::tempdir();
    let store = unthrottled(dir.path());
    let f = store.create_file("out").unwrap();
    let w = MergedWriter::new(f, usize::MAX);
    // Zero-length extent must neither merge-break nor write bytes.
    w.write(0, Vec::new());
    w.write(0, vec![9u8; 8]);
    w.write(8, vec![8u8; 8]);
    let report = w.finish().unwrap();
    assert_eq!(report.bytes, 16);
    assert_eq!(report.writes_out, 1);
    assert_eq!(store.size_of("out").unwrap(), 16);
}

#[test]
fn buffer_pool_reuse_under_contention() {
    // 8 threads × many get/put cycles against a small pool: retention
    // stays bounded, hit counting is monotone, and every buffer comes
    // back with the requested length.
    let dir = sem_spmm::util::tempdir();
    let store = unthrottled(dir.path());
    let pool = BufferPool::with_store(true, 4, store.clone());
    let hs: Vec<_> = (0..8usize)
        .map(|t| {
            let pool = pool.clone();
            std::thread::spawn(move || {
                for i in 0..500usize {
                    let len = 64 + ((t * 131 + i * 17) % 512);
                    let buf = pool.get(len);
                    assert_eq!(buf.len(), len);
                    pool.put(buf);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    assert!(pool.retained() <= 4, "retention bound violated");
    let hits = store.stats.pool_hits.get();
    let misses = store.stats.pool_misses.get();
    assert_eq!(hits + misses, 8 * 500);
    // Buffers must actually be reused under contention (the exact ratio
    // depends on scheduling, but zero reuse would mean a broken pool).
    assert!(hits > 0, "no pool reuse under contention");
}

#[test]
fn disabled_buffer_pool_counts_only_misses() {
    let dir = sem_spmm::util::tempdir();
    let store = unthrottled(dir.path());
    let pool = BufferPool::with_store(false, 16, store.clone());
    for _ in 0..50 {
        let b = pool.get(128);
        pool.put(b);
    }
    assert_eq!(pool.retained(), 0);
    assert_eq!(store.stats.pool_hits.get(), 0);
    assert_eq!(store.stats.pool_misses.get(), 50);
}

#[test]
fn slow_ssd_throttle_bounds_observed_read_gbps() {
    // slow_ssd(0.1): 100 MB/s read cap. Reading 8 MiB must take at least
    // ~80 ms, i.e. observed throughput <= ~1.3x the configured cap (the
    // slack covers timer granularity).
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::slow_ssd(dir.path(), 0.1)).unwrap();
    let data = vec![3u8; 8 << 20];
    store.put("obj", &data).unwrap();
    let read0 = store.stats.bytes_read.get();
    let t0 = Instant::now();
    let back = store.get("obj").unwrap();
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(back.len(), data.len());
    let gbps = (store.stats.bytes_read.get() - read0) as f64 / 1e9 / secs;
    assert!(gbps <= 0.13, "observed {gbps:.3} GB/s exceeds the 0.1 GB/s cap");
}

#[test]
fn slow_ssd_throttle_bounds_aggregate_write_gbps_across_threads() {
    // slow_ssd(0.25) → write cap 0.2 GB/s shared across threads.
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::slow_ssd(dir.path(), 0.25)).unwrap();
    let t0 = Instant::now();
    let hs: Vec<_> = (0..4)
        .map(|i| {
            let store = store.clone();
            std::thread::spawn(move || {
                let data = vec![i as u8; 2 << 20];
                store.put(&format!("w{i}"), &data).unwrap()
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let gbps = store.stats.bytes_written.get() as f64 / 1e9 / secs;
    assert!(gbps <= 0.26, "aggregate write {gbps:.3} GB/s exceeds the cap");
}

/// Build a weighted image large enough that a throttled SEM run is
/// firmly I/O-bound (>~15 MiB of tile data).
fn big_weighted_image() -> (Csr, Vec<u8>) {
    let el = rmat::generate(16, 3_000_000, rmat::RmatParams::default(), 0x5CA1E);
    let mut m = Csr::from_edgelist(&el);
    m.vals = Some((0..m.nnz()).map(|i| ((i % 113) as f32) * 0.01 + 0.5).collect());
    let img = TiledImage::build(&m, 512, TileFormat::Scsr);
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    (m, buf)
}

#[test]
fn sharded_store_scales_sem_read_throughput() {
    // Acceptance: 4 shards at 0.2 GB/s each must sustain >= 3x the
    // read_gbps of the identical single-shard run, and the striped SEM
    // output must still match IM-SpMM within the 1e-4 differential bound.
    let (m, buf) = big_weighted_image();
    let opts = SpmmOpts {
        threads: 4,
        io_workers: 2,
        ..Default::default()
    };
    let x = DenseMatrix::random(m.ncols, 1, 21);
    let img = Arc::new(TiledImage::from_bytes(&buf).unwrap());
    let (im_out, _) = engine::spmm_out(&Source::Mem(img), &x, &opts).unwrap();

    let mut gbps = Vec::new();
    for shards in [1usize, 4] {
        let dir = sem_spmm::util::tempdir();
        let store = ShardedStore::open(StoreSpec {
            dir: dir.path().to_path_buf(),
            shards,
            stripe_bytes: 128 << 10,
            read_gbps: Some(0.2),
            write_gbps: None,
            latency_us: 0,
            parity: false,
        })
        .unwrap();
        store.put("m.semm", &buf).unwrap();
        let sem = SemSource::open(&store, "m.semm").unwrap();
        let (sem_out, stats) = engine::spmm_out(&Source::Sem(sem), &x, &opts).unwrap();
        let diff = im_out.max_abs_diff(&sem_out);
        assert!(diff < 1e-4, "shards={shards}: IM vs SEM diff {diff}");
        assert!(stats.bytes_read > 8 << 20, "image too small to measure");
        gbps.push(stats.read_gbps);
    }
    assert!(
        gbps[1] >= 3.0 * gbps[0],
        "4-shard read throughput did not scale: 1 shard {:.3} GB/s, 4 shards {:.3} GB/s",
        gbps[0],
        gbps[1]
    );
}

#[test]
fn per_shard_stats_sum_to_logical_bytes() {
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec {
        dir: dir.path().to_path_buf(),
        shards: 3,
        stripe_bytes: 4096,
        read_gbps: None,
        write_gbps: None,
        latency_us: 0,
        parity: false,
    })
    .unwrap();
    let data: Vec<u8> = (0..100_000).map(|i| (i % 239) as u8).collect();
    store.put("obj", &data).unwrap();
    assert_eq!(store.get("obj").unwrap(), data);
    let physical: u64 = (0..3).map(|k| store.shard(k).stats.bytes_read.get()).sum();
    assert_eq!(physical, store.stats.bytes_read.get());
    let physical_w: u64 = (0..3)
        .map(|k| store.shard(k).stats.bytes_written.get())
        .sum();
    assert_eq!(physical_w, store.stats.bytes_written.get());
}
