//! Property-based tests over the coordinator's invariants: format
//! round-trips, scheduler coverage, engine-vs-reference equality, pass
//! planning and budget arithmetic — all under randomly generated inputs
//! (see `sem_spmm::util::proptest` for the harness; failures print a
//! replayable seed).

use sem_spmm::coordinator::batcher::{BatchConfig, BatchJob, Batcher};
use sem_spmm::coordinator::{MemBudget, PassPlan};
use sem_spmm::format::delta::DeltaOp;
use sem_spmm::graph::rmat;
use sem_spmm::format::tiled::{decode_all, TiledImage};
use sem_spmm::format::{dcsc, scsr, Csr, TileEntries, TileFormat, ValueType};
use sem_spmm::io::{DeltaConfig, DeltaStore, ShardedStore, StoreSpec};
use sem_spmm::matrix::DenseMatrix;
use sem_spmm::spmm::scheduler::Scheduler;
use sem_spmm::spmm::{engine, DeltaSource, Source, SpmmOpts};
use sem_spmm::util::proptest::{check, Gen};
use sem_spmm::VertexId;
use std::collections::BTreeMap;
use std::sync::Arc;

fn random_pairs(g: &mut Gen, nrows: usize, ncols: usize, n: usize) -> Vec<(VertexId, VertexId)> {
    let mut pairs: Vec<(VertexId, VertexId)> = (0..n)
        .map(|_| {
            (
                g.usize_in(0, nrows - 1) as VertexId,
                g.usize_in(0, ncols - 1) as VertexId,
            )
        })
        .collect();
    pairs.sort_unstable();
    pairs.dedup();
    pairs
}

fn random_tile(g: &mut Gen, t: usize, weighted: bool) -> TileEntries {
    let n = g.usize_in(1, 400);
    let mut coords: Vec<(u16, u16)> = (0..n)
        .map(|_| (g.usize_in(0, t - 1) as u16, g.usize_in(0, t - 1) as u16))
        .collect();
    coords.sort_unstable();
    coords.dedup();
    let vals = if weighted {
        coords.iter().map(|_| g.f32_in(0.1, 2.0)).collect()
    } else {
        Vec::new()
    };
    TileEntries { coords, vals }
}

#[test]
fn prop_scsr_roundtrip() {
    check("scsr-roundtrip", 60, |g| {
        let weighted = g.bool();
        let t = [64usize, 256, 1024][g.usize_in(0, 2)];
        let e = random_tile(g, t, weighted);
        let vt = if weighted { ValueType::F32 } else { ValueType::Binary };
        let mut buf = Vec::new();
        scsr::encode(3, &e, vt, &mut buf);
        let (view, end) = scsr::parse(&buf, 0, vt);
        if end != buf.len() {
            return Err(format!("parse end {end} != len {}", buf.len()));
        }
        let d = scsr::decode(&view, vt);
        if d.coords != e.coords {
            return Err("coords mismatch".into());
        }
        if weighted && d.vals != e.vals {
            return Err("vals mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dcsc_roundtrip_and_scsr_never_larger_when_sparse() {
    check("dcsc-roundtrip", 60, |g| {
        let t = 2048usize;
        let e = random_tile(g, t, false);
        let mut sb = Vec::new();
        let mut db = Vec::new();
        let s = scsr::encode(0, &e, ValueType::Binary, &mut sb);
        let d = dcsc::encode(0, &e, ValueType::Binary, &mut db);
        let (view, _) = dcsc::parse(&db, 0, ValueType::Binary);
        if dcsc::decode(&view, ValueType::Binary).coords != e.coords {
            return Err("dcsc decode mismatch".into());
        }
        // Paper's bound: 0.4 <= S_SCSR/S_DCSC < ~1 for binary matrices
        // at this sparsity (most rows hold <= a few entries).
        let ratio = s as f64 / d as f64;
        if !(0.3..=1.1).contains(&ratio) {
            return Err(format!("ratio {ratio} out of the paper's range"));
        }
        Ok(())
    });
}

#[test]
fn prop_tiled_image_preserves_every_entry() {
    check("tiled-image-roundtrip", 25, |g| {
        let nrows = g.usize_in(10, 1500);
        let ncols = g.usize_in(10, 1500);
        let n_pairs = g.usize_in(1, 4000);
        let pairs = random_pairs(g, nrows, ncols, n_pairs);
        if pairs.is_empty() {
            return Ok(());
        }
        let m = Csr::from_sorted_pairs(nrows, ncols, &pairs);
        let tile = [64usize, 128, 512][g.usize_in(0, 2)];
        let fmt = if g.bool() { TileFormat::Scsr } else { TileFormat::Dcsc };
        let img = TiledImage::build(&m, tile, fmt);
        let (coords, _) = decode_all(&img);
        let expect: Vec<(u32, u32)> = pairs.iter().map(|&(r, c)| (r, c)).collect();
        if coords != expect {
            return Err(format!(
                "decode mismatch: {} vs {} entries",
                coords.len(),
                expect.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_partitions_exactly() {
    check("scheduler-coverage", 80, |g| {
        let total = g.usize_in(0, 500);
        let grain = g.usize_in(1, 32);
        let threads = g.usize_in(1, 9);
        let dynamic = g.bool();
        let s = Scheduler::new(total, grain, threads, dynamic);
        let mut seen = vec![false; total];
        for th in 0..threads {
            while let Some(t) = s.claim(th) {
                for r in t.lo..t.hi {
                    if seen[r] {
                        return Err(format!("tile row {r} claimed twice"));
                    }
                    seen[r] = true;
                }
            }
        }
        if seen.iter().any(|&x| !x) {
            return Err("missed tile rows".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_concurrent_modes_claim_exactly_once() {
    // Satellite property: dynamic AND static modes claim every tile row
    // exactly once with no overlap, under real concurrent claiming —
    // including threads > total and grain > total shapes.
    check("scheduler-concurrent-exactly-once", 30, |g| {
        let total = g.usize_in(0, 300);
        let grain = g.usize_in(1, 40); // may exceed total
        let threads = g.usize_in(1, 10); // may exceed total
        for dynamic in [true, false] {
            let s = Arc::new(Scheduler::new(total, grain, threads, dynamic));
            let handles: Vec<_> = (0..threads)
                .map(|i| {
                    let s = s.clone();
                    std::thread::spawn(move || {
                        let mut mine = Vec::new();
                        while let Some(t) = s.claim(i) {
                            mine.extend(t.lo..t.hi);
                        }
                        mine
                    })
                })
                .collect();
            let mut all: Vec<usize> = handles
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .collect();
            all.sort_unstable();
            if all != (0..total).collect::<Vec<_>>() {
                return Err(format!(
                    "coverage broken: total={total} grain={grain} threads={threads} \
                     dynamic={dynamic}: claimed {} rows",
                    all.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_matches_reference() {
    check("engine-vs-reference", 12, |g| {
        let nrows = g.usize_in(50, 900);
        let ncols = g.usize_in(50, 900);
        let n_pairs = g.usize_in(10, 5000);
        let pairs = random_pairs(g, nrows, ncols, n_pairs);
        if pairs.is_empty() {
            return Ok(());
        }
        let mut m = Csr::from_sorted_pairs(nrows, ncols, &pairs);
        if g.bool() {
            m.vals = Some((0..m.nnz()).map(|_| g.f32_in(-1.0, 1.0)).collect());
        }
        let p = [1usize, 2, 3, 4, 8][g.usize_in(0, 4)];
        let tile = [64usize, 128][g.usize_in(0, 1)];
        let img = Arc::new(TiledImage::build(&m, tile, TileFormat::Scsr));
        let x = DenseMatrix::random(ncols, p, g.u64());
        let expect = m.spmm_ref(&x.data, p);
        let opts = SpmmOpts {
            threads: g.usize_in(1, 4),
            load_balance: g.bool(),
            cache_blocking: g.bool(),
            vectorize: g.bool(),
            ..Default::default()
        };
        let (got, _) = engine::spmm_out(&Source::Mem(img), &x, &opts)
            .map_err(|e| format!("engine: {e:#}"))?;
        for (i, (a, b)) in got.data.iter().zip(&expect).enumerate() {
            if (a - b).abs() > 1e-3 * b.abs().max(1.0) {
                return Err(format!("idx {i}: {a} vs {b} (p={p}, tile={tile})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pass_plan_covers_all_columns_within_budget() {
    check("pass-plan", 100, |g| {
        let n = g.usize_in(100, 1_000_000);
        let p = g.usize_in(1, 64);
        let cols_fit = g.usize_in(1, 64);
        let budget = MemBudget::new((n as u64) * 4 * cols_fit as u64);
        let plan = PassPlan::plan(n, p, &budget);
        if plan.panel_cols == 0 || plan.passes == 0 {
            return Err("degenerate plan".into());
        }
        // Passes cover p.
        if plan.panel_cols * plan.passes < p {
            return Err(format!(
                "plan {}x{} does not cover {p}",
                plan.panel_cols, plan.passes
            ));
        }
        // A panel fits the budget (except the mandatory single column).
        if plan.panel_cols > 1 && !budget.fits((n * 4 * plan.panel_cols) as u64) {
            return Err("panel exceeds budget".into());
        }
        Ok(())
    });
}

#[test]
fn prop_budget_accounting_never_goes_negative() {
    check("budget-accounting", 60, |g| {
        let budget = MemBudget::new(g.usize_in(1000, 100_000) as u64);
        let mut grants = Vec::new();
        for _ in 0..g.usize_in(1, 40) {
            if g.bool() {
                if let Ok(gr) = budget.alloc(g.usize_in(1, 5000) as u64) {
                    grants.push(gr);
                }
            } else if !grants.is_empty() {
                grants.remove(g.usize_in(0, grants.len() - 1));
            }
            if budget.used() > budget.limit() {
                return Err("over-committed".into());
            }
        }
        drop(grants);
        if budget.used() != 0 {
            return Err(format!("leak: {} bytes", budget.used()));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_never_drops_duplicates_or_cross_delivers() {
    // Batcher invariant: under arbitrary interleavings of concurrent
    // enqueues and dispatches (random batch size / linger), every
    // request resolves exactly once with exactly ITS result. Inputs are
    // integer-tagged constants against a binary matrix, so each rider's
    // correct output (`tag · rowdeg`) is exact in f32 — any drop,
    // duplicate or cross-delivery is a hard mismatch, not a tolerance
    // question.
    let el = rmat::generate(9, 4000, rmat::RmatParams::default(), 77);
    let m = Csr::from_edgelist(&el);
    let n = m.ncols;
    let rowdeg = m.spmm_ref(&vec![1f32; n], 1);
    let img = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
    check("batcher-delivery", 8, |g| {
        let src = Source::Mem(img.clone());
        let cfg = BatchConfig {
            max_riders: g.usize_in(1, 5),
            max_linger: std::time::Duration::from_millis(g.usize_in(0, 4) as u64),
            ..BatchConfig::default()
        };
        let opts = SpmmOpts {
            threads: g.usize_in(1, 3),
            ..Default::default()
        };
        let batcher = Batcher::new(opts, cfg).unwrap();
        const THREADS: usize = 3;
        const JOBS: usize = 4;
        let errs: Vec<String> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let batcher = &batcher;
                    let src = &src;
                    let rowdeg = &rowdeg;
                    scope.spawn(move || -> Vec<String> {
                        let mut errs = Vec::new();
                        let tickets: Vec<(u32, usize, _)> = (0..JOBS)
                            .map(|j| {
                                let tag = (t * JOBS + j + 1) as u32;
                                let p = 1 + (tag as usize % 3);
                                let x = sem_spmm::matrix::DenseMatrix::full(
                                    src.meta().ncols,
                                    p,
                                    tag as f32,
                                );
                                let tk = batcher
                                    .submit("k", src, BatchJob::forward(x, format!("t{tag}")))
                                    .unwrap();
                                (tag, p, tk)
                            })
                            .collect();
                        for (tag, p, tk) in tickets {
                            let r = match tk.wait() {
                                Ok(r) => r,
                                Err(e) => {
                                    errs.push(format!("tag {tag} dropped: {e:#}"));
                                    continue;
                                }
                            };
                            if r.output.ncols != p || r.output.nrows != rowdeg.len() {
                                errs.push(format!("tag {tag}: wrong shape"));
                                continue;
                            }
                            for (i, &v) in r.output.data.iter().enumerate() {
                                let want = tag as f32 * rowdeg[i / p];
                                if v != want {
                                    errs.push(format!(
                                        "tag {tag} row {}: got {v}, want {want} \
                                         (cross-delivery or corruption)",
                                        i / p
                                    ));
                                    break;
                                }
                            }
                        }
                        errs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("submitter panicked"))
                .collect()
        });
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        // Conservation: riders served == requests submitted, and no pass
        // ever exceeded the configured occupancy.
        let served = batcher.stats().riders.get();
        if served != (THREADS * JOBS) as u64 {
            return Err(format!("{served} riders served, expected {}", THREADS * JOBS));
        }
        Ok(())
    });
}

#[test]
fn prop_pass_rejects_exactly_the_aliased_plans() {
    // A pass must never carry two ops that write the same output, or an
    // op whose input is another op's output — and must accept every
    // non-aliased plan. Random plans over a pool of dense matrices probe
    // both sides of the predicate.
    // R-MAT edge lists produce square CSRs, so forward and transpose
    // op shapes coincide and one matrix pool serves both roles.
    let el = rmat::generate(8, 2000, rmat::RmatParams::default(), 79);
    let m = Csr::from_edgelist(&el);
    let n = m.nrows;
    assert_eq!(n, m.ncols, "rmat CSR must be square");
    let img = Arc::new(TiledImage::build(&m, 64, TileFormat::Scsr));
    check("pass-alias-rejection", 40, |g| {
        let opts = SpmmOpts::sequential();
        let cfg = sem_spmm::spmm::engine::numa_config(64, n, &opts);
        let ins: Vec<sem_spmm::matrix::NumaDense> = (0..3u64)
            .map(|i| {
                sem_spmm::matrix::NumaDense::from_dense(
                    &sem_spmm::matrix::DenseMatrix::random(n, 2, i),
                    cfg,
                )
            })
            .collect();
        let outs: Vec<sem_spmm::matrix::NumaDense> = (0..3)
            .map(|_| sem_spmm::matrix::NumaDense::zeros(n, 2, cfg))
            .collect();
        let n_ops = g.usize_in(1, 4);
        let mut pass = sem_spmm::spmm::StreamPass::new();
        let mut out_picks: Vec<usize> = Vec::new();
        let mut in_picks: Vec<usize> = Vec::new(); // 0..2 ins, 3..5 outs
        for _ in 0..n_ops {
            let ii = g.usize_in(0, 5);
            let oi = g.usize_in(0, 2);
            let input = if ii < 3 { &ins[ii] } else { &outs[ii - 3] };
            pass = if g.bool() {
                pass.forward(input, sem_spmm::spmm::OutputSink::Mem(&outs[oi]))
            } else {
                pass.transpose(input, &outs[oi])
            };
            in_picks.push(ii);
            out_picks.push(oi);
        }
        let mut expect_reject = false;
        for (k, &oi) in out_picks.iter().enumerate() {
            if out_picks[..k].contains(&oi) {
                expect_reject = true;
            }
            if in_picks.iter().any(|&ii| ii == oi + 3) {
                expect_reject = true;
            }
        }
        let r = sem_spmm::spmm::run_pass(&Source::Mem(img.clone()), &pass, &opts);
        match (expect_reject, r) {
            (true, Ok(_)) => Err("aliased plan accepted".into()),
            (false, Err(e)) => Err(format!("clean plan rejected: {e:#}")),
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_spmv_linearity() {
    // A(αx + βy) == αAx + βAy — exercised through the full engine.
    check("spmv-linearity", 15, |g| {
        let n = g.usize_in(100, 800);
        let n_pairs = g.usize_in(10, 3000);
        let pairs = random_pairs(g, n, n, n_pairs);
        if pairs.is_empty() {
            return Ok(());
        }
        let m = Csr::from_sorted_pairs(n, n, &pairs);
        let img = Arc::new(TiledImage::build(&m, 128, TileFormat::Scsr));
        let src = Source::Mem(img);
        let opts = SpmmOpts::sequential();
        let x: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let y: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let (alpha, beta) = (g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0));
        let combo: Vec<f32> = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| alpha * a + beta * b)
            .collect();
        let (ax, _) = engine::spmv(&src, &x, &opts).map_err(|e| e.to_string())?;
        let (ay, _) = engine::spmv(&src, &y, &opts).map_err(|e| e.to_string())?;
        let (ac, _) = engine::spmv(&src, &combo, &opts).map_err(|e| e.to_string())?;
        for i in 0..n {
            let expect = alpha * ax[i] + beta * ay[i];
            if (ac[i] - expect).abs() > 1e-2 * expect.abs().max(1.0) {
                return Err(format!("linearity broke at {i}: {} vs {expect}", ac[i]));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Delta layer (LSM edge updates): the merged view over base ⊕ runs must be
// exactly the reference edge set under ANY interleaving of stage / commit /
// compaction, and compaction must be idempotent and placement-insensitive.
// ---------------------------------------------------------------------------

/// Triggers disabled: commits and compactions happen only where the
/// property driver places them, never behind its back.
fn manual_delta_cfg() -> DeltaConfig {
    DeltaConfig {
        buffer_bytes: 64 << 20,
        compact_runs: usize::MAX,
        major_compact_ratio: f64::INFINITY,
    }
}

/// Random weighted base graph written to a fresh single-directory store
/// as `g.semm`, plus the matching reference edge map.
fn delta_fixture(
    g: &mut Gen,
) -> Option<(
    sem_spmm::util::TempDir,
    Arc<ShardedStore>,
    BTreeMap<(u32, u32), f32>,
    Vec<(u32, u32)>,
)> {
    let n = g.usize_in(64, 400);
    let pairs = random_pairs(g, n, n, g.usize_in(20, 1500));
    if pairs.is_empty() {
        return None;
    }
    let mut m = Csr::from_sorted_pairs(n, n, &pairs);
    m.vals = Some((0..m.nnz()).map(|_| g.f32_in(0.1, 2.0)).collect());
    let model: BTreeMap<(u32, u32), f32> = pairs
        .iter()
        .map(|&(r, c)| (r, c))
        .zip(m.vals.as_ref().unwrap().iter().copied())
        .collect();
    let img = TiledImage::build(&m, [64usize, 128][g.usize_in(0, 1)], TileFormat::Scsr);
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).ok()?;
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("g.semm", &buf).ok()?;
    let base_keys: Vec<(u32, u32)> = model.keys().copied().collect();
    Some((dir, store, model, base_keys))
}

/// The merged (base ⊕ committed runs) edge map, failing on any edge
/// emitted twice — a duplicate would double-count in every semiring.
fn merged_edge_map(
    store: &Arc<ShardedStore>,
    name: &str,
) -> Result<BTreeMap<(u32, u32), f32>, String> {
    let src = Source::Delta(DeltaSource::open(store, name).map_err(|e| format!("open: {e:#}"))?);
    let mut map = BTreeMap::new();
    let mut dup = None;
    src.for_each_edge(|r, c, v| {
        if map.insert((r, c), v).is_some() {
            dup = Some((r, c));
        }
    })
    .map_err(|e| format!("for_each_edge: {e:#}"))?;
    match dup {
        Some(k) => Err(format!("edge {k:?} emitted twice by the merged view")),
        None => Ok(map),
    }
}

fn diff_edge_maps(
    got: &BTreeMap<(u32, u32), f32>,
    want: &BTreeMap<(u32, u32), f32>,
) -> Result<(), String> {
    for (k, v) in want {
        match got.get(k) {
            None => return Err(format!("edge {k:?} dropped (model weight {v})")),
            Some(gv) if gv != v => {
                return Err(format!("edge {k:?}: weight {gv} != model {v}"));
            }
            _ => {}
        }
    }
    for k in got.keys() {
        if !want.contains_key(k) {
            return Err(format!("edge {k:?} resurrected/invented (not in model)"));
        }
    }
    Ok(())
}

#[test]
fn prop_delta_interleavings_never_drop_duplicate_or_resurrect() {
    // Arbitrary interleavings of upsert / delete / commit / run-compact /
    // major-compact, mirrored into a BTreeMap model. After a final
    // commit, the merged view must equal the model EXACTLY: weights pass
    // through as raw f32 bits, so equality is `==`, not a tolerance.
    check("delta-lsm-edge-set", 10, |g| {
        let Some((_dir, store, mut model, base_keys)) = delta_fixture(g) else {
            return Ok(());
        };
        let n = {
            let src = DeltaSource::open(&store, "g.semm").map_err(|e| e.to_string())?;
            src.base.meta.nrows as u32
        };
        let ds = DeltaStore::open(&store, "g.semm", manual_delta_cfg())
            .map_err(|e| format!("open delta: {e:#}"))?;

        // Deterministic delete → commit → resurrect of one base edge, so
        // every case proves a tombstone masks the base and a later upsert
        // punches back through it.
        let victim = base_keys[g.usize_in(0, base_keys.len() - 1)];
        ds.stage(DeltaOp::delete(victim.0, victim.1)).map_err(|e| e.to_string())?;
        model.remove(&victim);
        ds.commit().map_err(|e| e.to_string())?;
        ds.stage(DeltaOp::upsert(victim.0, victim.1, 9.25)).map_err(|e| e.to_string())?;
        model.insert(victim, 9.25);

        for _ in 0..g.usize_in(20, 120) {
            // A coordinate that often collides with a live edge, so
            // deletes and weight updates hit real targets.
            let key = if g.bool() {
                base_keys[g.usize_in(0, base_keys.len() - 1)]
            } else {
                (g.usize_in(0, n as usize - 1) as u32, g.usize_in(0, n as usize - 1) as u32)
            };
            match g.usize_in(0, 9) {
                0..=4 => {
                    let w = g.f32_in(0.1, 4.0);
                    ds.stage(DeltaOp::upsert(key.0, key.1, w)).map_err(|e| e.to_string())?;
                    model.insert(key, w);
                }
                5..=7 => {
                    ds.stage(DeltaOp::delete(key.0, key.1)).map_err(|e| e.to_string())?;
                    model.remove(&key);
                }
                8 => {
                    ds.commit().map_err(|e| e.to_string())?;
                }
                _ => {
                    ds.commit().map_err(|e| e.to_string())?;
                    if g.bool() {
                        ds.compact_runs().map_err(|e| e.to_string())?;
                    } else {
                        ds.major_compact().map_err(|e| e.to_string())?;
                    }
                }
            }
        }
        ds.commit().map_err(|e| e.to_string())?;
        let got = merged_edge_map(&store, "g.semm")?;
        diff_edge_maps(&got, &model)
    });
}

#[test]
fn prop_delta_compaction_is_idempotent_and_placement_insensitive() {
    // Two stores start from byte-identical bases and commit the same
    // batches; store A compacts aggressively after every commit, store B
    // never compacts until the end. Both merged views must equal the
    // model, and after each takes a single major compaction the new base
    // OBJECTS must be byte-identical (canonical-form bit-identity).
    // Re-running either compaction must be a no-op.
    check("delta-compaction-invariance", 8, |g| {
        let Some((_dir, store_a, mut model, base_keys)) = delta_fixture(g) else {
            return Ok(());
        };
        let dir_b = sem_spmm::util::tempdir();
        let store_b =
            ShardedStore::open(StoreSpec::unthrottled(dir_b.path())).map_err(|e| e.to_string())?;
        let base_bytes = store_a
            .read_object_unmetered("g.semm")
            .map_err(|e| e.to_string())?;
        store_b.put("g.semm", &base_bytes).map_err(|e| e.to_string())?;

        let n = base_keys.iter().map(|k| k.0.max(k.1)).max().unwrap() as usize + 1;
        let batches: Vec<Vec<DeltaOp>> = (0..g.usize_in(2, 6))
            .map(|_| {
                (0..g.usize_in(1, 60))
                    .map(|_| {
                        let key = if g.bool() {
                            base_keys[g.usize_in(0, base_keys.len() - 1)]
                        } else {
                            (g.usize_in(0, n - 1) as u32, g.usize_in(0, n - 1) as u32)
                        };
                        if g.usize_in(0, 2) == 0 {
                            DeltaOp::delete(key.0, key.1)
                        } else {
                            DeltaOp::upsert(key.0, key.1, g.f32_in(0.1, 4.0))
                        }
                    })
                    .collect()
            })
            .collect();

        let ds_a = DeltaStore::open(&store_a, "g.semm", manual_delta_cfg())
            .map_err(|e| e.to_string())?;
        let ds_b = DeltaStore::open(&store_b, "g.semm", manual_delta_cfg())
            .map_err(|e| e.to_string())?;
        for batch in &batches {
            for op in batch {
                ds_a.stage(*op).map_err(|e| e.to_string())?;
                ds_b.stage(*op).map_err(|e| e.to_string())?;
                if op.tombstone {
                    model.remove(&(op.row, op.col));
                } else {
                    model.insert((op.row, op.col), op.val);
                }
            }
            ds_a.commit().map_err(|e| e.to_string())?;
            ds_b.commit().map_err(|e| e.to_string())?;
            ds_a.compact_runs().map_err(|e| e.to_string())?; // A compacts every time
        }
        let map_a = merged_edge_map(&store_a, "g.semm")?;
        let map_b = merged_edge_map(&store_b, "g.semm")?;
        diff_edge_maps(&map_a, &model)?;
        if map_a != map_b {
            return Err("compaction placement changed the merged edge set".into());
        }

        // One major compaction each → canonical bases must be byte-equal.
        ds_a.major_compact().map_err(|e| e.to_string())?;
        ds_b.major_compact().map_err(|e| e.to_string())?;
        let man_a = ds_a.manifest().map_err(|e| e.to_string())?;
        let man_b = ds_b.manifest().map_err(|e| e.to_string())?;
        if !man_a.runs.is_empty() || !man_b.runs.is_empty() {
            return Err("major compaction left live runs".into());
        }
        let bytes_a = store_a
            .read_object_unmetered(&man_a.base)
            .map_err(|e| e.to_string())?;
        let bytes_b = store_b
            .read_object_unmetered(&man_b.base)
            .map_err(|e| e.to_string())?;
        if bytes_a != bytes_b {
            return Err(format!(
                "compacted bases diverge: {} vs {} bytes (or content)",
                bytes_a.len(),
                bytes_b.len()
            ));
        }
        diff_edge_maps(&merged_edge_map(&store_a, "g.semm")?, &model)?;

        // Idempotence: with nothing new staged, both compactions no-op
        // and the manifest is untouched.
        if ds_a.compact_runs().map_err(|e| e.to_string())? {
            return Err("compact_runs re-ran on an already-compacted store".into());
        }
        if ds_a.major_compact().map_err(|e| e.to_string())? {
            return Err("major_compact re-ran with no live runs".into());
        }
        if ds_a.manifest().map_err(|e| e.to_string())? != man_a {
            return Err("no-op compaction mutated the manifest".into());
        }
        Ok(())
    });
}
