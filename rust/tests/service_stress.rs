//! Service concurrency stress: many loopback clients hammering one
//! dataset with mixed traffic. Every reply must be bit-identical to a
//! serially computed reference, ride-sharing must actually happen
//! (batch occupancy > 1 observed), and the acceptance criterion of the
//! batching coordinator holds — 8 concurrent SPMM clients on a
//! throttled 4-shard dataset stream ≤ 2× one request's sparse bytes,
//! where serial serving streams 8×.

use sem_spmm::config::json::Json;
use sem_spmm::coordinator::batcher::{BatchConfig, BatchJob, Batcher};
use sem_spmm::coordinator::service::{fnv1a, Service};
use sem_spmm::coordinator::Catalog;
use sem_spmm::graph::registry;
use sem_spmm::io::{ShardedStore, StoreSpec};
use sem_spmm::matrix::DenseMatrix;
use sem_spmm::spmm::{engine, SemSource, Source, SpmmOpts};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn opts() -> SpmmOpts {
    SpmmOpts {
        threads: 2,
        ..Default::default()
    }
}

/// One line out, one JSON line back.
fn request(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    conn.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply '{line}': {e:#}"))
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("no numeric '{key}' in {j}"))
}

#[test]
fn eight_clients_mixed_traffic_bit_identical_with_sharing() {
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec::unthrottled(dir.path())).unwrap();
    let catalog = Catalog::new(store.clone(), 256);

    // Serial reference, computed before the service sees any traffic:
    // the same dataset the service will resolve ("twitter" shrunk to
    // scale 12), the same inputs (ones for SPMV; seed-1 random for SPMM).
    let spec = registry::by_name("twitter").unwrap().shrunk(12);
    let imgs = catalog.ensure(&spec).unwrap();
    let n = imgs.num_verts;
    let src = Source::Sem(catalog.open_adj(&imgs).unwrap());
    let mut want_check = std::collections::HashMap::new();
    for p in [4usize, 8] {
        let x = DenseMatrix::random(n, p, 1);
        let (out, _) = engine::spmm_out(&src, &x, &opts()).unwrap();
        want_check.insert(p, format!("{:016x}", fnv1a(&out.to_le_bytes())));
    }
    let nnz = imgs.nnz as f64;

    let svc = Arc::new(
        Service::with_batch(
            catalog,
            opts(),
            BatchConfig {
                max_riders: 8,
                max_linger: Duration::from_millis(60),
                ..BatchConfig::default()
            },
        )
        .unwrap(),
    );
    let stop = svc.stop_handle();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let svc = svc.clone();
        std::thread::spawn(move || svc.serve_listener(listener))
    };

    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let want_check = Arc::new(want_check);
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let barrier = barrier.clone();
            let want_check = want_check.clone();
            std::thread::spawn(move || -> u64 {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                let r = request(&mut conn, &mut reader, "PING");
                assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
                let r = request(&mut conn, &mut reader, "INFO twitter");
                assert_eq!(num(&r, "nnz"), nnz, "client {c}: INFO nnz");
                // All clients fire their SPMM together so the linger can
                // coalesce them; widths 4 and 8 share the same sweep.
                let p = if c % 2 == 0 { 4 } else { 8 };
                barrier.wait();
                let r = request(&mut conn, &mut reader, &format!("SPMM twitter {p}"));
                assert!(
                    r.get("error").is_none(),
                    "client {c}: SPMM error {r}"
                );
                assert_eq!(
                    r.get("check").and_then(|v| v.as_str()),
                    Some(want_check[&p].as_str()),
                    "client {c}: SPMM p={p} not bit-identical to serial"
                );
                let riders = num(&r, "riders") as u64;
                assert!((1..=8).contains(&riders));
                // Amortization accounting is self-consistent.
                let pass_bytes = num(&r, "sparse_bytes");
                let per_rider = num(&r, "sparse_bytes_per_rider");
                assert!(per_rider <= pass_bytes);
                // SPMV afterwards: ones vector sums to nnz exactly.
                let r = request(&mut conn, &mut reader, "SPMV twitter");
                assert_eq!(num(&r, "sum"), nnz, "client {c}: SPMV sum");
                conn.write_all(b"QUIT\n").unwrap();
                riders
            })
        })
        .collect();
    let max_riders_seen = handles
        .into_iter()
        .map(|h| h.join().expect("client panicked"))
        .max()
        .unwrap();

    assert!(
        max_riders_seen > 1,
        "no SPMM reply reported sharing (max riders {max_riders_seen})"
    );
    let stats = svc.batch_stats();
    assert!(stats.occupancy_max.get() > 1, "occupancy never exceeded 1");
    assert!(stats.shared_passes.get() >= 1);
    assert!(
        stats.amortization() > 1.0,
        "sharing must amortize sparse bytes: {}",
        stats.summary()
    );

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

/// The tentpole acceptance criterion, at the batcher level: 8 concurrent
/// SPMM requests against one throttled 4-shard dataset read ≤ 2× one
/// request's logical sparse bytes (vs exactly 8× served serially), with
/// every reply bit-identical to its serial twin — and `max_riders = 1`
/// reproduces the serial byte count exactly.
#[test]
fn eight_concurrent_spmm_clients_amortize_sparse_reads() {
    let dir = sem_spmm::util::tempdir();
    let store = ShardedStore::open(StoreSpec {
        dir: dir.path().to_path_buf(),
        shards: 4,
        stripe_bytes: 64 << 10,
        read_gbps: Some(0.5), // 2 GB/s aggregate — throttled but quick
        write_gbps: None,
        latency_us: 10,
        parity: false,
    })
    .unwrap();
    let el = sem_spmm::graph::rmat::generate(
        11,
        40_000,
        sem_spmm::graph::rmat::RmatParams::default(),
        7,
    );
    let m = sem_spmm::format::Csr::from_edgelist(&el);
    let img = sem_spmm::format::tiled::TiledImage::build(
        &m,
        256,
        sem_spmm::format::TileFormat::Scsr,
    );
    let mut buf = Vec::new();
    img.write_to(&mut buf).unwrap();
    store.put("m.semm", &buf).unwrap();

    const CLIENTS: usize = 8;
    let p = 4usize;
    let xs: Vec<DenseMatrix> = (0..CLIENTS)
        .map(|i| DenseMatrix::random(m.ncols, p, 70 + i as u64))
        .collect();

    // Serial baseline: one engine invocation per request.
    let src = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
    let read0 = store.stats.bytes_read.get();
    let serial: Vec<DenseMatrix> = xs
        .iter()
        .map(|x| engine::spmm_out(&src, x, &opts()).unwrap().0)
        .collect();
    let serial_bytes = store.stats.bytes_read.get() - read0;
    let single_bytes = serial_bytes / CLIENTS as u64;
    assert!(single_bytes > 0);
    assert_eq!(
        serial_bytes,
        single_bytes * CLIENTS as u64,
        "serial requests must each stream the matrix once"
    );

    // Batched: all 8 submit concurrently; the linger coalesces them.
    let run_batched = |max_riders: usize| -> (u64, Vec<DenseMatrix>, u64) {
        let batcher = Batcher::new(
            opts(),
            BatchConfig {
                max_riders,
                max_linger: Duration::from_millis(100),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let src = Source::Sem(SemSource::open(&store, "m.semm").unwrap());
        let read0 = store.stats.bytes_read.get();
        let barrier = Barrier::new(CLIENTS);
        let outs: Vec<DenseMatrix> = std::thread::scope(|scope| {
            let handles: Vec<_> = xs
                .iter()
                .enumerate()
                .map(|(i, x)| {
                    let batcher = &batcher;
                    let src = &src;
                    let barrier = &barrier;
                    scope.spawn(move || {
                        barrier.wait();
                        batcher
                            .run("m", src, BatchJob::forward(x.clone(), format!("c{i}")))
                            .unwrap()
                            .output
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let bytes = store.stats.bytes_read.get() - read0;
        (bytes, outs, batcher.stats().occupancy_max.get())
    };

    let (batched_bytes, batched, occupancy) = run_batched(8);
    for (i, (a, b)) in batched.iter().zip(&serial).enumerate() {
        assert_eq!(a.data, b.data, "client {i}: batched != serial");
    }
    assert!(occupancy > 1, "no sharing happened");
    assert!(
        batched_bytes <= 2 * single_bytes,
        "8 riders read {batched_bytes} bytes; budget is 2x one request ({single_bytes})"
    );

    // Batch size 1 degrades exactly to serial per-request behavior.
    let (solo_bytes, solo_outs, solo_occ) = run_batched(1);
    assert_eq!(solo_occ, 1);
    assert_eq!(
        solo_bytes, serial_bytes,
        "max_riders=1 must stream exactly what serial serving streams"
    );
    for (a, b) in solo_outs.iter().zip(&serial) {
        assert_eq!(a.data, b.data, "max_riders=1 output differs from serial");
    }
}
