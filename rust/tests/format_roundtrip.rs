//! Satellite unit tests for `format/`: full round-trips
//! COO pairs → CSR → tiled SCSR/DCSC image → bytes → parse/decode →
//! equality, on Erdős–Rényi, R-MAT and degenerate (empty / single-row)
//! graphs, all with deterministic `util::prng` seeds.

use sem_spmm::format::tiled::{decode_all, read_header, TiledImage};
use sem_spmm::format::{dcsc, scsr, Csr, TileEntries, TileFormat, ValueType};
use sem_spmm::graph::{erdos, rmat};

/// Sorted global (row, col) pairs of a CSR matrix — the decode oracle.
fn csr_pairs(m: &Csr) -> Vec<(u32, u32)> {
    (0..m.nrows)
        .flat_map(|r| m.row(r).iter().map(move |&c| (r as u32, c)))
        .collect()
}

fn roundtrip_image(m: &Csr, tile: usize, fmt: TileFormat) {
    let img = TiledImage::build(m, tile, fmt);
    assert_eq!(img.meta.nnz as usize, m.nnz());
    let (coords, vals) = decode_all(&img);
    assert_eq!(coords, csr_pairs(m), "tile={tile} fmt={fmt:?}");
    if let Some(mv) = &m.vals {
        let expect: Vec<f32> = (0..m.nrows)
            .flat_map(|r| m.row_vals(r).unwrap().iter().copied())
            .collect();
        assert_eq!(vals, expect);
        assert_eq!(vals.len(), mv.len());
    } else {
        assert!(vals.is_empty());
    }
}

#[test]
fn erdos_roundtrips_scsr_and_dcsc_across_tiles() {
    let el = erdos::generate(700, 5_000, 0xE1);
    let m = Csr::from_edgelist(&el);
    for tile in [64usize, 128, 512, 1024] {
        roundtrip_image(&m, tile, TileFormat::Scsr);
        roundtrip_image(&m, tile, TileFormat::Dcsc);
    }
}

#[test]
fn rmat_roundtrips_scsr_and_dcsc() {
    let el = rmat::generate(11, 25_000, rmat::RmatParams::default(), 0x12A7);
    let m = Csr::from_edgelist(&el);
    for tile in [128usize, 256] {
        roundtrip_image(&m, tile, TileFormat::Scsr);
        roundtrip_image(&m, tile, TileFormat::Dcsc);
    }
}

#[test]
fn weighted_rmat_roundtrips_values() {
    let el = rmat::generate(10, 9_000, rmat::RmatParams::default(), 0x77);
    let mut m = Csr::from_edgelist(&el);
    let mut rng = sem_spmm::util::Xoshiro256::new(0xBEEF);
    m.vals = Some((0..m.nnz()).map(|_| rng.next_f32() + 0.25).collect());
    roundtrip_image(&m, 128, TileFormat::Scsr);
    roundtrip_image(&m, 128, TileFormat::Dcsc);
}

#[test]
fn empty_graph_builds_empty_image() {
    // Zero rows.
    let m = Csr::from_sorted_pairs(0, 0, &[]);
    let img = TiledImage::build(&m, 128, TileFormat::Scsr);
    assert_eq!(img.meta.n_tile_rows(), 0);
    assert_eq!(img.data_bytes(), 0);
    let (coords, vals) = decode_all(&img);
    assert!(coords.is_empty() && vals.is_empty());

    // Rows but no entries: every tile row is present and empty.
    let m = Csr::from_sorted_pairs(300, 300, &[]);
    let img = TiledImage::build(&m, 64, TileFormat::Scsr);
    assert_eq!(img.meta.n_tile_rows(), 5);
    assert!(img.index.iter().all(|&(_, len)| len == 0));
    let (coords, _) = decode_all(&img);
    assert!(coords.is_empty());
}

#[test]
fn single_row_and_single_entry_graphs() {
    // One row holding every entry (stresses the SCSR multi-row path).
    let pairs: Vec<(u32, u32)> = (0..40u32).map(|c| (0, c * 3)).collect();
    let m = Csr::from_sorted_pairs(1, 120, &pairs);
    roundtrip_image(&m, 64, TileFormat::Scsr);
    roundtrip_image(&m, 64, TileFormat::Dcsc);

    // A single entry (the COO single-entry-row path).
    let m = Csr::from_sorted_pairs(10, 10, &[(4, 7)]);
    let img = TiledImage::build(&m, 16, TileFormat::Scsr);
    let (coords, _) = decode_all(&img);
    assert_eq!(coords, vec![(4, 7)]);
}

#[test]
fn serialized_image_bytes_reparse_identically() {
    let el = erdos::generate(400, 3_000, 0x5E);
    let m = Csr::from_edgelist(&el);
    for fmt in [TileFormat::Scsr, TileFormat::Dcsc] {
        let img = TiledImage::build(&m, 128, fmt);
        let dir = sem_spmm::util::tempdir();
        let p = dir.path().join("img.semm");
        img.save(&p).unwrap();
        // Header-only read agrees with the in-memory metadata...
        let mut f = std::fs::File::open(&p).unwrap();
        let (meta, index, _) = read_header(&mut f).unwrap();
        assert_eq!(meta, img.meta);
        assert_eq!(index, img.index);
        // ...and the full reload decodes to the same entries.
        let img2 = TiledImage::load(&p).unwrap();
        let (c1, v1) = decode_all(&img);
        let (c2, v2) = decode_all(&img2);
        assert_eq!(c1, c2);
        assert_eq!(v1, v2);
    }
}

#[test]
fn tile_encoders_agree_on_identical_entries() {
    // SCSR and DCSC encode the same logical tile; decoding both yields
    // identical sorted entries (and the deterministic seed reproduces).
    let mut rng = sem_spmm::util::Xoshiro256::new(42);
    let t = 512u64;
    let mut coords: Vec<(u16, u16)> = (0..1500)
        .map(|_| (rng.below(t) as u16, rng.below(t) as u16))
        .collect();
    coords.sort_unstable();
    coords.dedup();
    let vals: Vec<f32> = coords.iter().map(|_| rng.next_f32() + 0.1).collect();
    let e = TileEntries { coords, vals };

    let mut sb = Vec::new();
    scsr::encode(5, &e, ValueType::F32, &mut sb);
    let (sv, s_end) = scsr::parse(&sb, 0, ValueType::F32);
    assert_eq!(s_end, sb.len());
    let sd = scsr::decode(&sv, ValueType::F32);

    let mut db = Vec::new();
    dcsc::encode(5, &e, ValueType::F32, &mut db);
    let (dv, d_end) = dcsc::parse(&db, 0, ValueType::F32);
    assert_eq!(d_end, db.len());
    let dd = dcsc::decode(&dv, ValueType::F32);

    assert_eq!(sd.coords, e.coords);
    assert_eq!(dd.coords, e.coords);
    assert_eq!(sd.vals, e.vals);
    assert_eq!(dd.vals, e.vals);
}
