//! `cargo bench` target for Fig 12: compute-optimization ablation.
mod common;

fn main() {
    let (_dir, bench) = common::bench_ctx("fig12");
    sem_spmm::bench::run(&bench, "fig12").expect("fig12");
}
