//! Shared scaffolding for the `cargo bench` targets. Each bench target is
//! a thin front end over `sem_spmm::bench` (the paper-figure harness) at
//! a bench-friendly scale: `cargo bench` must finish in minutes, so these
//! run at scale 13 by default; `SEM_BENCH_SCALE` overrides.

use sem_spmm::bench::Bench;

pub fn bench_ctx(name: &str) -> (sem_spmm::util::TempDir, Bench) {
    let scale: u32 = std::env::var("SEM_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(13);
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(8);
    let shards: usize = std::env::var("SEM_BENCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let dir = sem_spmm::util::tempdir();
    let bench = Bench::new(
        Bench::array_spec(
            dir.path().join("store"),
            12.0,
            shards,
            sem_spmm::io::DEFAULT_STRIPE_BYTES,
        ),
        std::path::PathBuf::from("results").join("bench"),
        threads,
        Some(scale),
        4096,
    )
    .expect("bench context");
    eprintln!("[{name}] scale={scale} threads={threads} gbps=12 shards={shards}");
    (dir, bench)
}
