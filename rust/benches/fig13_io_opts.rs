//! `cargo bench` target for Fig 13: I/O-optimization ablation.
mod common;

fn main() {
    let (_dir, bench) = common::bench_ctx("fig13");
    sem_spmm::bench::run(&bench, "fig13").expect("fig13");
}
