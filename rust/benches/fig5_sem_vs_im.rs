//! `cargo bench` target for Fig 5: SEM vs IM SpMM across dense widths.
mod common;

fn main() {
    let (_dir, bench) = common::bench_ctx("fig5");
    sem_spmm::bench::run(&bench, "fig5a").expect("fig5");
}
