//! `cargo bench` target for Fig 7: IM/SEM vs MKL-like vs Tpetra-like.
mod common;

fn main() {
    let (_dir, bench) = common::bench_ctx("fig7");
    sem_spmm::bench::run(&bench, "fig7").expect("fig7");
}
