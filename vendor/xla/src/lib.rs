//! Compile-only stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links `libxla_extension`; this container (and CI) has
//! neither the library nor network access to fetch it, so the `pjrt`
//! cargo feature resolves to this stub instead. It reproduces exactly the
//! API surface `sem_spmm::runtime::xla` uses:
//!
//! * construction succeeds ([`PjRtClient::cpu`], [`Literal`] builders,
//!   [`HloModuleProto::from_text_file`] parsing/validation of paths), so
//!   the runtime's artifact-discovery and failure paths behave like the
//!   real thing;
//! * anything that would require the XLA runtime itself (compiling or
//!   executing a computation) returns an [`Error`] explaining that the
//!   stub is active.
//!
//! Swapping in the real bindings is a one-line change in the root
//! `Cargo.toml` (point the `xla` dependency at the real crate); no source
//! changes are needed.

use std::fmt;
use std::path::Path;

/// Stub error: a message, `Debug`-printable like the real crate's error.
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: xla stub active (libxla not linked; this build validates the PJRT code path only)"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Sealed-ish marker for native element types accepted by [`Literal::vec1`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le_bytes_vec(items: &[Self]) -> Vec<u8>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le_bytes_vec(items: &[Self]) -> Vec<u8> {
        items.iter().flat_map(|v| v.to_le_bytes()).collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_le_bytes_vec(items: &[Self]) -> Vec<u8> {
        items.iter().flat_map(|v| v.to_le_bytes()).collect()
    }
}

/// A host literal: raw little-endian bytes plus a shape.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    bytes: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            bytes: T::to_le_bytes_vec(data),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error(format!(
                "reshape: {have} elements into shape {dims:?}"
            )));
        }
        Ok(Literal {
            ty: self.ty,
            bytes: self.bytes.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Unpack a 1-tuple result. Real executions never reach this in the
    /// stub (execute fails first).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("to_tuple1"))
    }

    /// Copy the payload out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("to_vec"))
    }
}

/// Parsed HLO module (stub: retains nothing but validity).
#[derive(Debug, Clone)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse an HLO-text artifact. The stub validates that the file exists
    /// and plausibly is HLO text (starts with "HloModule"), which keeps
    /// the runtime's missing/garbage-artifact error paths realistic.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(Path::new(path))
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error(format!("{path}: not HLO text")));
        }
        Ok(HloModuleProto {})
    }
}

/// A computation built from an [`HloModuleProto`].
#[derive(Debug, Clone)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A device buffer handle (never actually produced by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("to_literal_sync"))
    }
}

/// A compiled executable (never actually produced by the stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs. Always fails in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execute"))
    }
}

/// A PJRT client. Construction succeeds (mirrors the real CPU client);
/// compilation fails with a stub error.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {})
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn client_constructs_but_compile_fails() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {});
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn hlo_text_validation() {
        let dir = std::env::temp_dir();
        let good = dir.join("xla_stub_good.hlo.txt");
        let bad = dir.join("xla_stub_bad.hlo.txt");
        std::fs::write(&good, "HloModule test\nROOT x = f32[] constant(0)").unwrap();
        std::fs::write(&bad, "not hlo").unwrap();
        assert!(HloModuleProto::from_text_file(good.to_str().unwrap()).is_ok());
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        std::fs::remove_file(good).ok();
        std::fs::remove_file(bad).ok();
    }
}
