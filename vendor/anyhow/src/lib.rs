//! A small, offline, API-compatible subset of the `anyhow` crate.
//!
//! The container this repo builds in has no network access, so instead of
//! a registry dependency the workspace vendors the slice of anyhow's API
//! the codebase actually uses:
//!
//! * [`Error`] — an opaque error carrying a message chain (outermost
//!   context first). Like real anyhow, it deliberately does **not**
//!   implement `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` impl coherent.
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Formatting matches anyhow closely enough for logs and tests: `{}`
//! prints the outermost message, `{:#}` prints the whole chain joined
//! with `": "`, and `{:?}` prints the chain in anyhow's
//! "Caused by" layout.

use std::fmt;

/// An opaque error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// The anyhow trick: `Error` itself is not `std::error::Error`, so this
// blanket impl does not overlap the identity `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (and to `None`).
pub trait Context<T>: Sized {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format-style arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from format-style arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening store object").unwrap_err();
        assert_eq!(format!("{e}"), "opening store object");
        assert_eq!(format!("{e:#}"), "opening store object: missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("nope").is_err());
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
